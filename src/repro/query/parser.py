"""Cypher-lite pattern parser.

Grammar (see README.md in this package for the prose version)::

    pattern := node (edge node)*
    node    := '(' [ident] [':' alts] [props] ')'
    edge    := '-' '[' body ']' '->'  |  '<-' '[' body ']' '-'
    body    := [ident] [':' alts] ['*' [bounds]] [props]
    bounds  := int | int '..' | int '..' int | '..' int
    alts    := value ('|' value)*
    props   := '{' pred (',' pred)* '}'
    pred    := ident op literal        ;  op ∈ {=, ==, !=, <, <=, >, >=}
    literal := number | quoted string | bareword

Hand-rolled recursive descent over a regex token stream — no parser
dependency, exact source positions in errors.  ``=`` normalizes to ``==``;
numeric literals become int/float so predicate masks compare natively
against the typed property columns.  ``*`` bounds mark variable-length
hops: ``*`` = 1..∞, ``*k`` = exactly k, ``*lo..hi``/``*lo..``/``*..hi``
with the missing end defaulting to 1 / ∞ (see README "Variable-length
hops").  Variable names must be unique across the whole pattern: a
repeated variable would read as an equality join, which the engine does
not implement — it is rejected here rather than silently mis-meaning.
"""
from __future__ import annotations

import re
from typing import List, Optional, Tuple, Union

from repro.query.ast import EdgePattern, NodePattern, Pattern, Predicate

__all__ = ["parse", "ParseError"]


class ParseError(ValueError):
    """Pattern syntax error, with position context."""


# NB ordering: arrows before comparison ops ('->' vs '>'), numbers before
# punct so a signed literal like '-3' beats the lone '-' edge dash.  A '<'
# immediately followed by '-' always reads as an incoming edge, so negative
# literals after '<' need a space: '{age < -3}'.
_TOKEN_RE = re.compile(
    r"""\s*(?:
        (?P<arrow_in>\<\-)        # <-
      | (?P<arrow_out>\-\>)       # ->
      | (?P<dotdot>\.\.)          # range in '*lo..hi' (before number)
      | (?P<op>==|!=|<=|>=|=|<|>)
      | (?P<string>"[^"]*"|'[^']*')
      | (?P<number>[+-]?\d+\.(?!\.)\d*(?:[eE][+-]?\d+)?|[+-]?\.?\d+(?:[eE][+-]?\d+)?)
      | (?P<punct>[()\[\]{}:,|\-*])
      | (?P<ident>[A-Za-z_][A-Za-z0-9_.]*)
    )""",
    re.VERBOSE,
)


def _tokenize(text: str) -> List[Tuple[str, str, int]]:
    toks, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None or m.end() == m.start():
            rest = text[pos:].lstrip()
            if not rest:
                break
            raise ParseError(f"unexpected character {rest[0]!r} at position {pos} in {text!r}")
        kind = m.lastgroup
        toks.append((kind, m.group(kind), m.start(kind)))
        pos = m.end()
    return toks


class _Cursor:
    def __init__(self, text: str):
        self.text = text
        self.toks = _tokenize(text)
        self.i = 0

    def peek(self) -> Optional[Tuple[str, str, int]]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> Tuple[str, str, int]:
        tok = self.peek()
        if tok is None:
            raise ParseError(f"unexpected end of pattern in {self.text!r}")
        self.i += 1
        return tok

    def expect(self, value: str) -> None:
        kind, val, pos = self.next()
        if val != value:
            raise ParseError(
                f"expected {value!r} but found {val!r} at position {pos} in {self.text!r}"
            )

    def accept(self, value: str) -> bool:
        tok = self.peek()
        if tok is not None and tok[1] == value:
            self.i += 1
            return True
        return False


def _literal(cur: _Cursor) -> Union[int, float, str]:
    kind, val, pos = cur.next()
    if kind == "string":
        return val[1:-1]
    if kind == "number":
        return float(val) if any(c in val for c in ".eE") else int(val)
    if kind == "ident":
        return val
    raise ParseError(f"expected a literal, found {val!r} at position {pos} in {cur.text!r}")


def _alts(cur: _Cursor) -> Tuple[str, ...]:
    """``a|b|c`` after a ':' — attribute values, OR semantics (§VI)."""
    out = [str(_literal(cur))]
    while cur.accept("|"):
        out.append(str(_literal(cur)))
    return tuple(out)


def _props(cur: _Cursor) -> Tuple[Predicate, ...]:
    if not cur.accept("{"):
        return ()
    preds = []
    while True:
        kind, name, pos = cur.next()
        if kind != "ident":
            raise ParseError(
                f"expected property name, found {name!r} at position {pos} in {cur.text!r}"
            )
        kind, op, pos = cur.next()
        if kind != "op":
            raise ParseError(
                f"expected comparison operator, found {op!r} at position {pos} in {cur.text!r}"
            )
        preds.append(Predicate(name=name, op="==" if op == "=" else op, value=_literal(cur)))
        if cur.accept("}"):
            return tuple(preds)
        cur.expect(",")


def _entity_body(cur: _Cursor) -> Tuple[Optional[str], Tuple[str, ...]]:
    """Shared leading interior of node ``(...)`` and edge ``[...]``:
    optional variable, optional ``:alts``.  Props (and, for edges, the
    ``*`` bounds that precede them) are parsed by the callers."""
    var = None
    tok = cur.peek()
    if tok is not None and tok[0] == "ident":
        var = cur.next()[1]
    labels: Tuple[str, ...] = ()
    if cur.accept(":"):
        labels = _alts(cur)
    return var, labels


def _bound_int(cur: _Cursor) -> int:
    kind, val, pos = cur.next()
    if kind != "number" or not val.isdigit():
        raise ParseError(
            f"traversal bounds must be non-negative integers, found {val!r} "
            f"at position {pos} in {cur.text!r}"
        )
    return int(val)


def _star_bounds(cur: _Cursor) -> Tuple[int, Optional[int]]:
    """``*`` [bounds] after an edge's alts: (lo, hi), hi=None = unbounded."""
    if not cur.accept("*"):
        return 1, 1
    tok = cur.peek()
    if tok is not None and tok[0] == "number":
        lo = _bound_int(cur)
        if cur.accept(".."):
            tok = cur.peek()
            hi = _bound_int(cur) if tok is not None and tok[0] == "number" else None
        else:
            hi = lo  # '*k' — exactly k hops
    elif tok is not None and tok[0] == "dotdot":
        cur.next()
        lo, hi = 1, _bound_int(cur)  # '*..hi'
    else:
        lo, hi = 1, None  # bare '*'
    if hi is not None and hi < lo:
        raise ParseError(
            f"traversal upper bound below lower (*{lo}..{hi}) in {cur.text!r}"
        )
    return lo, hi


def _node(cur: _Cursor) -> NodePattern:
    cur.expect("(")
    var, labels = _entity_body(cur)
    preds = _props(cur)
    cur.expect(")")
    return NodePattern(var=var, labels=labels, predicates=preds)


def _edge(cur: _Cursor) -> EdgePattern:
    """``-[...]->`` or ``<-[...]-`` (the only two directed forms)."""
    kind, val, pos = cur.next()
    incoming = kind == "arrow_in"
    if not incoming and val != "-":
        raise ParseError(f"expected edge, found {val!r} at position {pos} in {cur.text!r}")
    cur.expect("[")
    var, rels = _entity_body(cur)
    lo, hi = _star_bounds(cur)
    preds = _props(cur)
    cur.expect("]")
    if incoming:
        cur.expect("-")
    else:
        kind, val, pos = cur.next()
        if kind != "arrow_out":
            raise ParseError(
                f"expected '->' closing an edge, found {val!r} at position {pos} "
                f"in {cur.text!r}"
            )
    return EdgePattern(var=var, rels=rels, predicates=preds,
                       direction=-1 if incoming else 1, lo=lo, hi=hi)


def parse(text: str) -> Pattern:
    """Parse a pattern string into a :class:`Pattern` AST.

    Raises ``ParseError`` on a repeated variable name: the engine does not
    implement equality joins, so ``(a)-[:r]->(a)`` would silently mean
    something different from what it reads as (see README).
    """
    cur = _Cursor(text)
    nodes = [_node(cur)]
    edges = []
    while cur.peek() is not None:
        edges.append(_edge(cur))
        nodes.append(_node(cur))
    seen = set()
    for ent in (*nodes, *edges):
        if ent.var is not None:
            if ent.var in seen:
                raise ParseError(
                    f"variable {ent.var!r} is bound more than once in {text!r}: "
                    "repeated variables would read as an equality join, which "
                    "this engine does not implement — use distinct names"
                )
            seen.add(ent.var)
    return Pattern(nodes=tuple(nodes), edges=tuple(edges))
