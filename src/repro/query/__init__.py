"""repro.query — declarative property-graph pattern engine.

Pattern text → AST (``parse``) → plan (``plan_pattern``) → fused execution
(``execute_plan``) over ``DIGraph`` + the DIP attribute stores.  The public
entry points on ``PropGraph`` are ``match()`` / ``explain()``; this package
is the machinery behind them.
"""
from repro.query.ast import EdgePattern, NodePattern, Pattern, Predicate
from repro.query.executor import MatchResult, execute_plan, execute_plan_with_masks
from repro.query.parser import ParseError, parse
from repro.query.plan import MaskStep, Plan, PredicateStep
from repro.query.planner import plan_pattern
from repro.query.weights import edge_weight_values

__all__ = [
    "Pattern",
    "NodePattern",
    "EdgePattern",
    "Predicate",
    "parse",
    "ParseError",
    "Plan",
    "MaskStep",
    "PredicateStep",
    "plan_pattern",
    "MatchResult",
    "execute_plan",
    "execute_plan_with_masks",
    "edge_weight_values",
]
