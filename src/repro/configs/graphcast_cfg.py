"""graphcast [arXiv:2212.12794]: 16 processor layers, d_hidden=512,
mesh_refinement=6, sum aggregation, 227 variables."""
from repro.models.graphcast import GraphCastConfig

FAMILY = "gnn"
ARCH_ID = "graphcast"
MODEL = "graphcast"


def full_config() -> GraphCastConfig:
    return GraphCastConfig(name=ARCH_ID, n_layers=16, d_hidden=512, n_vars=227,
                           mesh_refinement=6, aggregator="sum")


def smoke_config() -> GraphCastConfig:
    return GraphCastConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=32, n_vars=8)
