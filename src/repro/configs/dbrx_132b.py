"""dbrx-132b [hf:databricks/dbrx-base]: 40L d6144 48H (GQA kv=8) ff10752
v100352, MoE 16 experts top-4 (fine-grained); full attention."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

FAMILY = "lm"
ARCH_ID = "dbrx-132b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=10752, vocab=100352, pattern=("global",),
        n_experts=16, top_k=4, moe_renorm="full", act="silu", gated=True,
        rope_theta=5e5, dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=96, vocab=512, pattern=("global",),
        n_experts=4, top_k=2, moe_renorm="full", dtype=jnp.float32,
        loss_chunk=32, attn_impl="direct",
    )
