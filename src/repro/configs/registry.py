"""Architecture registry: ``--arch <id>`` resolution for the whole framework.

Maps the 10 assigned architecture ids to their config modules, enumerates the
40 (arch × shape) dry-run cells (with the documented long_500k skips), and
builds (step_kind, input ShapeDtypeStructs) per cell.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.configs import common
from repro.configs import (  # noqa: F401
    dbrx_132b, dimenet_cfg, dlrm_rm2, gcn_cora, gemma2_9b, graphcast_cfg,
    mace_cfg, mixtral_8x22b, qwen2_72b, starcoder2_7b,
)

__all__ = ["ARCHS", "get_arch", "arch_shapes", "list_cells", "cell_specs", "SKIPPED_CELLS"]

ARCHS = {
    "mixtral-8x22b": mixtral_8x22b,
    "dbrx-132b": dbrx_132b,
    "gemma2-9b": gemma2_9b,
    "qwen2-72b": qwen2_72b,
    "starcoder2-7b": starcoder2_7b,
    "gcn-cora": gcn_cora,
    "mace": mace_cfg,
    "dimenet": dimenet_cfg,
    "graphcast": graphcast_cfg,
    "dlrm-rm2": dlrm_rm2,
}

# long_500k runs only for archs with a sub-quadratic mechanism (SWA);
# pure full-attention archs are skipped per the assignment (DESIGN.md §4).
SKIPPED_CELLS = {
    ("dbrx-132b", "long_500k"): "pure full-attention (no SWA) — long_500k skipped",
    ("qwen2-72b", "long_500k"): "pure full-attention (no SWA) — long_500k skipped",
    ("starcoder2-7b", "long_500k"): "pure full-attention (no SWA) — long_500k skipped",
}


def get_arch(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def arch_shapes(arch_id: str) -> List[str]:
    fam = get_arch(arch_id).FAMILY
    table = {"lm": common.LM_SHAPES, "gnn": common.GNN_SHAPES,
             "recsys": common.RECSYS_SHAPES}[fam]
    return list(table)


def list_cells() -> List[Tuple[str, str, Optional[str]]]:
    """All 40 (arch, shape, skip_reason|None) cells."""
    cells = []
    for a in ARCHS:
        for s in arch_shapes(a):
            cells.append((a, s, SKIPPED_CELLS.get((a, s))))
    return cells


def cell_specs(arch_id: str, shape_name: str):
    """(kind, specs, cfg) for one dry-run cell — specs are SDS pytrees."""
    mod = get_arch(arch_id)
    fam = mod.FAMILY
    if fam == "lm":
        cfg = mod.full_config()
        kind, specs = common.lm_input_specs(cfg, shape_name)
        return kind, specs, cfg
    if fam == "gnn":
        if mod.MODEL == "graphcast":
            cfg = mod.full_config()
            specs = common.gc_specs(shape_name, n_vars=cfg.n_vars, d_edge=cfg.d_edge)
            return "train", specs, cfg
        if mod.MODEL == "gcn":
            d_feat = common.GNN_SHAPES[shape_name].get("d_feat") or 128
            n_classes = {"full_graph_sm": 7, "ogb_products": 47}.get(shape_name, 16)
            cfg = mod.full_config(d_feat=d_feat, n_classes=n_classes)
        else:
            cfg = mod.full_config()
        specs = common.gnn_graph_specs(shape_name, model=mod.MODEL)
        return "train", specs, cfg
    if fam == "recsys":
        cfg = mod.full_config()
        kind, specs = common.recsys_input_specs(cfg, shape_name)
        return kind, specs, cfg
    raise ValueError(fam)
