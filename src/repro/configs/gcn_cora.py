"""gcn-cora [arXiv:1609.02907]: 2 layers, d_hidden=16, mean/sym aggregation."""
from repro.models.gcn import GCNConfig

FAMILY = "gnn"
ARCH_ID = "gcn-cora"
MODEL = "gcn"


def full_config(d_feat: int = 1433, n_classes: int = 7) -> GCNConfig:
    return GCNConfig(name=ARCH_ID, n_layers=2, d_in=d_feat, d_hidden=16,
                     n_classes=n_classes, norm="sym", aggregator="mean")


def smoke_config() -> GCNConfig:
    return GCNConfig(name=ARCH_ID + "-smoke", n_layers=2, d_in=32, d_hidden=8,
                     n_classes=4)
