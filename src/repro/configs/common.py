"""Shared config machinery: shape tables and input_specs builders per family.

``input_specs(arch, shape)`` returns ``(step_kind, specs)`` where specs are
ShapeDtypeStruct pytrees — weak-type-correct, shardable, never allocated —
exactly what ``jax.jit(...).lower(**specs)`` consumes in the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn_common import GraphBatch
from repro.models.graphcast import GCBatch

__all__ = [
    "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES", "sds",
    "lm_input_specs", "gnn_graph_specs", "gc_specs", "recsys_input_specs",
    "TRIPLET_CAP_FACTOR", "MINIBATCH_SUBGRAPH",
]

sds = jax.ShapeDtypeStruct

# ---------------------------------------------------------------- shape tables
LM_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433, kind="train"),
    "minibatch_lg": dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                         fanout=(15, 10), d_feat=602, kind="train"),
    "ogb_products": dict(n_nodes=2449029, n_edges=61859140, d_feat=100, kind="train"),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, kind="train"),
}

RECSYS_SHAPES = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, kind="retrieval"),
}

TRIPLET_CAP_FACTOR = 8

# Entity/edge arrays are padded to multiples of 512 (= lcm of every mesh-axis
# group they shard over: dp=16, dp·pod=32, dp·pod·model=512) — pjit requires
# evenly-divisible input shardings; masks carry validity (the production
# padding discipline, same as the sampler's).
PAD_QUANTUM = 512


def pad512(n: int) -> int:
    return -(-n // PAD_QUANTUM) * PAD_QUANTUM


# ------------------------------------------------------------------ LM specs
def lm_input_specs(cfg, shape_name: str):
    """(kind, specs).  Returns None for long_500k on pure full-attention archs
    (sub-quadratic gate — DESIGN.md §4)."""
    from repro.models.transformer import init_cache

    sh = LM_SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    if sh["kind"] == "train":
        return "train", {
            "tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32),
        }
    if sh["kind"] == "prefill":
        return "prefill", {"tokens": sds((B, S), jnp.int32)}
    # decode: one new token against a seq_len-deep KV cache
    if shape_name == "long_500k" and cfg.window is None:
        return None, None  # skipped: pure full-attention arch
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return "decode", {"tokens": sds((B, 1), jnp.int32), "cache": cache}


# ----------------------------------------------------------------- GNN specs
def minibatch_subgraph_sizes(batch_nodes: int, fanout) -> tuple:
    """Static worst-case compacted-subgraph size for sampled training: union of
    all sampler blocks (repro.graph.sampler.block_shapes collapsed)."""
    n = batch_nodes
    total_nodes = n
    total_edges = 0
    frontier = n
    for f in fanout:
        total_edges += frontier * f
        frontier = frontier * (f + 1)
        total_nodes = frontier
    return total_nodes, total_edges


MINIBATCH_SUBGRAPH = minibatch_subgraph_sizes  # alias


def _gnn_sizes(shape_name: str):
    sh = GNN_SHAPES[shape_name]
    if shape_name == "minibatch_lg":
        n, e = minibatch_subgraph_sizes(sh["batch_nodes"], sh["fanout"])
        return pad512(n), pad512(e), sh.get("d_feat")
    if shape_name == "molecule":
        b = sh["batch"]
        return pad512(sh["n_nodes"] * b), pad512(sh["n_edges"] * b), sh.get("d_feat")
    return pad512(sh["n_nodes"]), pad512(sh["n_edges"]), sh.get("d_feat")


def gnn_graph_specs(shape_name: str, *, model: str, n_classes: int = 47,
                    n_species: int = 16) -> GraphBatch:
    """GraphBatch of ShapeDtypeStructs adapted per model family:
    gcn — dense features + node labels; mace/dimenet — species+pos (+triplets),
    graph energies.  (graphcast uses gc_specs.)"""
    n, e, d_feat = _gnn_sizes(shape_name)
    n_graphs = GNN_SHAPES[shape_name].get("batch", 1) if shape_name == "molecule" else 1
    f32, i32 = jnp.float32, jnp.int32
    if model == "gcn":
        x, pos, species, tri = sds((n, d_feat or 128), f32), None, None, None
        labels = sds((n,), i32)
    else:
        x, pos, species = None, sds((n, 3), f32), sds((n,), i32)
        tri = sds((TRIPLET_CAP_FACTOR * e, 3), i32) if model == "dimenet" else None
        labels = sds((n_graphs,), f32)
    return GraphBatch(
        x=x, pos=pos, species=species,
        edge_src=sds((e,), i32), edge_dst=sds((e,), i32), edge_attr=tri,
        edge_mask=sds((e,), jnp.bool_), node_mask=sds((n,), jnp.bool_),
        labels=labels, graph_ids=sds((n,), i32),
        n_nodes=n, n_edges=e, n_graphs=n_graphs,
    )


def gc_specs(shape_name: str, *, n_vars: int, d_edge: int = 4) -> GCBatch:
    from repro.data.graph import graphcast_sizes

    n, e, _ = _gnn_sizes(shape_name)
    ng, nm, ne_g2m, ne_mesh, ne_m2g = graphcast_sizes(n, e)
    f32, i32 = jnp.float32, jnp.int32
    return GCBatch(
        grid_x=sds((ng, n_vars), f32),
        g2m_src=sds((ne_g2m,), i32), g2m_dst=sds((ne_g2m,), i32),
        g2m_attr=sds((ne_g2m, d_edge), f32),
        mesh_src=sds((ne_mesh,), i32), mesh_dst=sds((ne_mesh,), i32),
        mesh_attr=sds((ne_mesh, d_edge), f32),
        m2g_src=sds((ne_m2g,), i32), m2g_dst=sds((ne_m2g,), i32),
        m2g_attr=sds((ne_m2g, d_edge), f32),
        targets=sds((ng, n_vars), f32),
        n_grid=ng, n_mesh=nm, n_g2m=ne_g2m, n_mesh_e=ne_mesh, n_m2g=ne_m2g,
    )


# -------------------------------------------------------------- recsys specs
def recsys_input_specs(cfg, shape_name: str):
    sh = RECSYS_SHAPES[shape_name]
    B = sh["batch"]
    f32, i32 = jnp.float32, jnp.int32
    base = {
        "dense": sds((B, cfg.n_dense), f32),
        "sparse": sds((B, cfg.n_sparse, cfg.multi_hot), i32),
    }
    if sh["kind"] == "train":
        return "train", {**base, "labels": sds((B,), i32)}
    if sh["kind"] == "retrieval":
        return "retrieval", {**base,
                             "candidates": sds((pad512(sh["n_candidates"]), cfg.embed_dim), f32)}
    return "serve", base
