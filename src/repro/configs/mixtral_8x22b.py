"""mixtral-8x22b [arXiv:2401.04088]: 56L d6144 48H (GQA kv=8) ff16384 v32768,
MoE 8 experts top-2, sliding-window attention."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

FAMILY = "lm"
ARCH_ID = "mixtral-8x22b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=16384, vocab=32768, window=4096, pattern=("local",),
        n_experts=8, top_k=2, moe_renorm="topk", act="silu", gated=True,
        rope_theta=1e6, dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=512, window=16, pattern=("local",),
        n_experts=4, top_k=2, act="silu", gated=True, dtype=jnp.float32,
        loss_chunk=32, attn_impl="direct",
    )
