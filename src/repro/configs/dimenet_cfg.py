"""dimenet [arXiv:2003.03123]: 6 blocks, d_hidden=128, 8 bilinear units,
7 spherical × 6 radial basis functions."""
from repro.models.dimenet import DimeNetConfig

FAMILY = "gnn"
ARCH_ID = "dimenet"
MODEL = "dimenet"


def full_config() -> DimeNetConfig:
    return DimeNetConfig(name=ARCH_ID, n_blocks=6, d_hidden=128, n_bilinear=8,
                         n_spherical=7, n_radial=6)


def smoke_config() -> DimeNetConfig:
    return DimeNetConfig(name=ARCH_ID + "-smoke", n_blocks=2, d_hidden=16,
                         n_bilinear=4, n_spherical=3, n_radial=3, n_species=4)
