"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse fields, embed 64,
bottom MLP 13-512-256-64, top MLP 512-512-256-1, dot interaction."""
from repro.models.dlrm import DLRMConfig

FAMILY = "recsys"
ARCH_ID = "dlrm-rm2"


def full_config() -> DLRMConfig:
    return DLRMConfig(name=ARCH_ID, n_dense=13, n_sparse=26, embed_dim=64,
                      vocab_size=1_000_000, bot_mlp=(13, 512, 256, 64),
                      top_mlp=(512, 512, 256, 1), interaction="dot")


def smoke_config() -> DLRMConfig:
    return DLRMConfig(name=ARCH_ID + "-smoke", vocab_size=500,
                      bot_mlp=(13, 32, 16, 8), embed_dim=8,
                      top_mlp=(32, 16, 1))
