"""starcoder2-7b [arXiv:2402.19173]: 32L d4608 36H (GQA kv=4) ff18432 v49152;
GQA + RoPE, non-gated GELU FFN, full attention."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

FAMILY = "lm"
ARCH_ID = "starcoder2-7b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_head=128,
        d_ff=18432, vocab=49152, pattern=("global",), act="gelu", gated=False,
        rope_theta=1e5, dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=512, pattern=("global",), act="gelu", gated=False,
        dtype=jnp.float32, loss_chunk=32, attn_impl="direct",
    )
