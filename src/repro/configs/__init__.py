"""repro.configs — one module per assigned architecture (+ shared machinery).

Selectable via ``--arch <id>`` in the launchers; see registry.ARCHS.
"""
