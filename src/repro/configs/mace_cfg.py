"""mace [arXiv:2206.07697]: 2 layers, 128 channels, l_max=2, correlation 3,
8 radial Bessel functions, E(3)-equivariant (Cartesian-irreps TPU form)."""
from repro.models.mace import MACEConfig

FAMILY = "gnn"
ARCH_ID = "mace"
MODEL = "mace"


def full_config() -> MACEConfig:
    return MACEConfig(name=ARCH_ID, n_layers=2, channels=128, l_max=2,
                      correlation=3, n_rbf=8)


def smoke_config() -> MACEConfig:
    return MACEConfig(name=ARCH_ID + "-smoke", n_layers=2, channels=16, n_rbf=4,
                      n_species=4)
