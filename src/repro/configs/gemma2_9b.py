"""gemma2-9b [arXiv:2408.00118]: 42L d3584 16H (GQA kv=8, d_head=256) ff14336
v256000; alternating local(4096)/global layers, logit softcaps, GeGLU,
post-norms, scaled embeddings."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

FAMILY = "lm"
ARCH_ID = "gemma2-9b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_head=256,
        d_ff=14336, vocab=256000, window=4096, pattern=("local", "global"),
        attn_softcap=50.0, final_softcap=30.0, post_norms=True, scale_embed=True,
        act="gelu", gated=True, tie_embeddings=True, dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=512, window=16, pattern=("local", "global"),
        attn_softcap=50.0, final_softcap=30.0, post_norms=True, scale_embed=True,
        act="gelu", gated=True, tie_embeddings=True, dtype=jnp.float32,
        loss_chunk=32, attn_impl="direct",
    )
