"""qwen2-72b [arXiv:2407.10671]: 80L d8192 64H (GQA kv=8) ff29568 v152064;
QKV bias, full attention."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

FAMILY = "lm"
ARCH_ID = "qwen2-72b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=29568, vocab=152064, pattern=("global",), qkv_bias=True,
        rope_theta=1e6, act="silu", gated=True, dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=512, pattern=("global",), qkv_bias=True,
        dtype=jnp.float32, loss_chunk=32, attn_impl="direct",
    )
