"""Typed-graph analytics: the paper's §VI queries composed with §I's algorithms.

These extend the paper's "returned values can be further processed" pattern
into first-class operations: every algorithm takes attribute masks and runs
on the typed subgraph WITHOUT materializing it (mask-composed, all jittable).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.di import DIGraph
from repro.core.property_graph import PropGraph
from repro.core.queries import connected_entities, filtered_bfs
from repro.graph.algorithms import pagerank

__all__ = ["khop_typed", "label_histogram", "typed_components", "attribute_assortativity"]


@partial(jax.jit, static_argnames=("k",))
def khop_typed(g: DIGraph, seeds: jax.Array, edge_allowed: jax.Array, *, k: int) -> jax.Array:
    """Vertices within k typed hops of the seeds: (n,) bool."""
    mask = jnp.zeros((g.n,), jnp.bool_).at[seeds].set(True)
    for _ in range(k):
        relax = mask[g.src] & edge_allowed
        mask = mask | jnp.zeros_like(mask).at[g.dst].max(relax)
    return mask


def label_histogram(pg: PropGraph) -> Tuple[np.ndarray, list]:
    """Counts per vertex label (the attribute-statistics query a data
    scientist runs first; paper Fig. 1 exploration pattern).  Same numbers
    the pattern planner reads for selectivity (``_AttrStore.attr_counts``)."""
    return pg._vstore.attr_counts(), pg.label_set()


def typed_components(pg: PropGraph, relationships: Sequence[str],
                     *, max_iters: int = 64) -> jax.Array:
    """Connected components of the subgraph induced by the given relationship
    types (mask-composed label propagation; no subgraph materialization)."""
    g = pg._require_graph()
    e_ok = pg.query_relationships(relationships)
    labels0 = jnp.arange(g.n, dtype=jnp.int32)

    def body(state):
        labels, _, it = state
        m1 = jnp.minimum(labels[g.src], labels[g.dst])
        big = jnp.int32(2 ** 30)
        upd_dst = jnp.where(e_ok, m1, big)
        upd_src = jnp.where(e_ok, m1, big)
        new = labels.at[g.dst].min(upd_dst)
        new = new.at[g.src].min(upd_src)
        new = new[new]
        return new, jnp.any(new != labels), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    labels, _, _ = jax.lax.while_loop(
        cond, body, (labels0, jnp.bool_(True), jnp.int32(0)))
    return labels


def attribute_assortativity(pg: PropGraph, labels: Sequence[str]) -> float:
    """Fraction of edges whose endpoints share membership of the queried label
    set — a one-number mixing statistic over the property graph."""
    g = pg._require_graph()
    vm = pg.query_labels(labels)
    same = vm[g.src] & vm[g.dst]
    either = vm[g.src] | vm[g.dst]
    denom = jnp.maximum(jnp.sum(either), 1)
    return float(jnp.sum(same) / denom)
