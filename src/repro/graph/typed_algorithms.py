"""Typed-graph analytics: the paper's §VI queries composed with §I's algorithms.

These extend the paper's "returned values can be further processed" pattern
into first-class operations: every algorithm takes attribute masks and runs
on the typed subgraph WITHOUT materializing it (mask-composed, all jittable).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.di import DIGraph
from repro.core.property_graph import PropGraph
from repro.core.queries import connected_entities, filtered_bfs
from repro.graph.algorithms import pagerank

__all__ = ["khop_typed", "label_histogram", "typed_components", "attribute_assortativity"]


def khop_typed(g: DIGraph, seeds: jax.Array, edge_allowed: jax.Array, *, k: int) -> jax.Array:
    """Vertices within k typed hops of the seeds: (n,) bool.  Runs through
    the frontier engine (``repro.traverse.khop_mask`` — one jitted
    ``while_loop`` with early exit instead of k unrolled relaxations)."""
    from repro.traverse import khop_mask

    mask = jnp.zeros((g.n,), jnp.bool_).at[seeds].set(True)
    return khop_mask(g, mask, edge_allowed, k=k)


def label_histogram(pg: PropGraph) -> Tuple[np.ndarray, list]:
    """Counts per vertex label (the attribute-statistics query a data
    scientist runs first; paper Fig. 1 exploration pattern).  Same numbers
    the pattern planner reads for selectivity (``_AttrStore.attr_counts``)."""
    return pg._vstore.attr_counts(), pg.label_set()


def typed_components(pg: PropGraph, relationships: Sequence[str],
                     *, max_iters: int = 64) -> jax.Array:
    """Connected components of the subgraph induced by the given relationship
    types (mask-composed label propagation; no subgraph materialization).
    Frontier-engine client: every vertex participates (singletons where the
    typed edges don't reach); ``PropGraph.components(pattern=...)`` is the
    richer form with label/predicate filters and -1 outside the filter."""
    from repro.traverse import components_masked

    g = pg._require_graph()
    e_ok = pg.query_relationships(relationships)
    return components_masked(g, None, e_ok, max_iters=max_iters)


def attribute_assortativity(pg: PropGraph, labels: Sequence[str]) -> float:
    """Fraction of edges whose endpoints share membership of the queried label
    set — a one-number mixing statistic over the property graph."""
    g = pg._require_graph()
    vm = pg.query_labels(labels)
    same = vm[g.src] & vm[g.dst]
    either = vm[g.src] | vm[g.dst]
    denom = jnp.maximum(jnp.sum(either), 1)
    return float(jnp.sum(same) / denom)
