"""Message-passing primitives over DI edge arrays.

JAX sparse is BCOO-only, so (per the assignment and kernel taxonomy §GNN)
message passing is implemented via ``jax.ops.segment_*`` over the edge-index →
node scatter.  DI's sort invariant (edges sorted by src, and by dst in the
reverse view) makes ``indices_are_sorted=True`` legal, which XLA exploits.

``gather_scatter`` is the generic MPNN primitive; ``spmm_di`` the GCN-style
Ã·X product.  Both have a Pallas MXU formulation in ``repro.kernels.seg_mm``
(selected with ``impl='kernel'``).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "segment_sum_sorted",
    "segment_mean",
    "segment_max",
    "segment_min",
    "segment_softmax",
    "gather_scatter",
    "spmm_di",
    "degree_norm",
]


def segment_sum_sorted(data, segment_ids, num_segments: int):
    """segment_sum with the DI sortedness promise."""
    return jax.ops.segment_sum(
        data, segment_ids, num_segments=num_segments, indices_are_sorted=True
    )


def segment_mean(data, segment_ids, num_segments: int, *, sorted_ids: bool = False):
    s = jax.ops.segment_sum(data, segment_ids, num_segments, indices_are_sorted=sorted_ids)
    cnt = jax.ops.segment_sum(
        jnp.ones(data.shape[:1], data.dtype), segment_ids, num_segments,
        indices_are_sorted=sorted_ids,
    )
    return s / jnp.maximum(cnt, 1)[(...,) + (None,) * (data.ndim - 1)]


def segment_max(data, segment_ids, num_segments: int, *, sorted_ids: bool = False):
    return jax.ops.segment_max(data, segment_ids, num_segments, indices_are_sorted=sorted_ids)


def segment_min(data, segment_ids, num_segments: int, *, sorted_ids: bool = False):
    return jax.ops.segment_min(data, segment_ids, num_segments, indices_are_sorted=sorted_ids)


def segment_softmax(scores, segment_ids, num_segments: int):
    """Numerically-stable per-segment softmax (GAT edge softmax)."""
    seg_max = jax.ops.segment_max(scores, segment_ids, num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(scores - seg_max[segment_ids])
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-30)


def gather_scatter(
    x: jax.Array,
    src_idx: jax.Array,
    dst_idx: jax.Array,
    num_nodes: int,
    *,
    msg_fn: Optional[Callable] = None,
    edge_weight: Optional[jax.Array] = None,
    agg: str = "sum",
) -> jax.Array:
    """The MPNN primitive: m_e = msg(x[src_e]); h_v = ⨁_{e: dst_e=v} m_e.

    x: (n, d) node features; src_idx/dst_idx: (m,) DI edge arrays.
    """
    msgs = x[src_idx]
    if msg_fn is not None:
        msgs = msg_fn(msgs)
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None]
    if agg == "sum":
        return jax.ops.segment_sum(msgs, dst_idx, num_nodes)
    if agg == "mean":
        return segment_mean(msgs, dst_idx, num_nodes)
    if agg == "max":
        out = jax.ops.segment_max(msgs, dst_idx, num_nodes)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(f"unknown agg {agg!r}")


def degree_norm(src_idx, dst_idx, num_nodes: int, *, mode: str = "sym") -> jax.Array:
    """GCN normalization coefficients per edge.

    sym:  1/sqrt((1+deg_out(u))·(1+deg_in(v)))  (self-loop-adjusted, Kipf §2)
    rw:   1/(1+deg_in(v))
    """
    ones = jnp.ones_like(src_idx, jnp.float32)
    d_out = jax.ops.segment_sum(ones, src_idx, num_nodes) + 1.0
    d_in = jax.ops.segment_sum(ones, dst_idx, num_nodes) + 1.0
    if mode == "sym":
        return jax.lax.rsqrt(d_out[src_idx] * d_in[dst_idx])
    if mode == "rw":
        return 1.0 / d_in[dst_idx]
    raise ValueError(f"unknown mode {mode!r}")


def spmm_di(
    x: jax.Array,
    src_idx: jax.Array,
    dst_idx: jax.Array,
    num_nodes: int,
    *,
    edge_weight: Optional[jax.Array] = None,
    impl: str = "segment",
) -> jax.Array:
    """Ã @ X over DI edges. impl='segment' (XLA) or 'kernel' (Pallas seg_mm)."""
    if impl == "kernel":
        from repro.kernels.seg_mm import ops as _ops

        return _ops.seg_mm(x, src_idx, dst_idx, num_nodes, edge_weight=edge_weight)
    return gather_scatter(x, src_idx, dst_idx, num_nodes, edge_weight=edge_weight, agg="sum")
