"""repro.graph — graph substrate: segment ops, generators, sampling, analytics."""
from repro.graph.algorithms import connected_components, pagerank, triangle_count
from repro.graph.generators import (
    PAPER_GRAPHS,
    attach_random_attributes,
    paper_graph,
    random_uniform_graph,
    rmat_graph,
)
from repro.graph.sampler import (
    SampledBlock,
    block_shapes,
    layer_key,
    layer_keys_batch,
    local_block,
    sample_block,
    sample_layers,
)
from repro.graph.segment_ops import (
    degree_norm,
    gather_scatter,
    segment_mean,
    segment_softmax,
    spmm_di,
)

__all__ = [
    "connected_components",
    "pagerank",
    "triangle_count",
    "PAPER_GRAPHS",
    "attach_random_attributes",
    "paper_graph",
    "random_uniform_graph",
    "rmat_graph",
    "SampledBlock",
    "block_shapes",
    "layer_key",
    "layer_keys_batch",
    "local_block",
    "sample_block",
    "sample_layers",
    "degree_norm",
    "gather_scatter",
    "segment_mean",
    "segment_softmax",
    "spmm_di",
]
