"""Graph generators.

``random_uniform_graph`` reproduces the paper's §VII-A dataset regime: two
endpoint arrays of length m filled with uniform integers from a pool of size m
("we set the random vertex integers created to be that of the same size as
number of edges to minimize the amount of multiple edges"), giving
n ≈ 0.865·m distinct vertices and avg degree ≈ 1 — matching Tab. I exactly
(graph1: n=86,503 ≈ 0.865e5 for m=1e5).  Attribute assignment mirrors §VII-A:
a pool of ``n_attrs`` (=50) labels/relationships sampled uniformly with
replacement, "some vertices or edges could be repeated and some not selected".

``rmat_graph`` adds the standard Graph500 power-law generator for structure-
sensitive benchmarks (the paper defers structure effects to future work; we
include it so the harness can probe them).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = ["random_uniform_graph", "rmat_graph", "attach_random_attributes", "paper_graph"]

# the paper's Tab. I ladder: name -> number of edges
PAPER_GRAPHS = {
    "graph1": 100_000,
    "graph2": 1_000_000,
    "graph3": 10_000_000,
    "graph4": 100_000_000,
    "graph5": 1_000_000_000,
}


def random_uniform_graph(m: int, *, seed: int = 0, vertex_pool: Optional[int] = None):
    """§VII-A generator: (src, dst) uniform over a pool of size ``m``."""
    rng = np.random.default_rng(seed)
    pool = m if vertex_pool is None else vertex_pool
    src = rng.integers(0, pool, size=m, dtype=np.int64)
    dst = rng.integers(0, pool, size=m, dtype=np.int64)
    return src, dst


def rmat_graph(scale: int, edge_factor: int = 16, *, a=0.57, b=0.19, c=0.19, seed: int = 0):
    """Graph500 R-MAT: 2**scale vertices, edge_factor·2**scale edges."""
    rng = np.random.default_rng(seed)
    n_edges = edge_factor * (1 << scale)
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    for lvl in range(scale):
        r = rng.random(n_edges)
        src_bit = r > (a + b)
        dst_bit = ((r > a) & (r <= a + b)) | (r > (a + b + c))
        src |= src_bit.astype(np.int64) << lvl
        dst |= dst_bit.astype(np.int64) << lvl
    return src, dst


def attach_random_attributes(
    n_entities: int, *, n_attrs: int = 50, coverage: float = 1.0, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """§VII-A attribute assignment: each selected entity draws one attribute
    uniformly from a pool of ``n_attrs`` (paper sets 50 for both labels and
    relationships).  ``coverage`` < 1 leaves some entities bare (the paper's
    'some not selected at all')."""
    rng = np.random.default_rng(seed)
    cnt = int(n_entities * coverage)
    entities = rng.choice(n_entities, size=cnt, replace=True).astype(np.int64)
    attrs = rng.integers(0, n_attrs, size=cnt, dtype=np.int64)
    return entities, attrs


def paper_graph(name: str, *, scale_down: int = 1, seed: int = 0):
    """Tab. I graph, optionally scaled down by ``scale_down`` (CPU container
    cannot hold 1e9 edges; benchmarks report the scale factor alongside)."""
    m = PAPER_GRAPHS[name] // scale_down
    return random_uniform_graph(m, seed=seed)
