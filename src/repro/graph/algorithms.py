"""Graph analytics kernels over DI (the Arachne kernel suite, §I/§III).

All kernels are edge-centric (iterate the block-distributed edge list) per the
DI design — "DI enhances CSR by explicitly listing all edges to facilitate both
edge-based and vertex-based algorithms" — and are pure/jittable/pjit-shardable.
BFS lives in ``repro.core.queries`` (property-filtered form).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.di import DIGraph

__all__ = ["connected_components", "pagerank", "triangle_count", "degree_histogram"]


def connected_components(g: DIGraph, *, max_iters: int = 128) -> jax.Array:
    """Label propagation (Shiloach-Vishkin style min-hook): (n,) component ids.
    Treats edges as undirected.  Converges in O(diameter) rounds.

    Thin alias for the frontier engine's masked implementation with no
    masks (``repro.traverse.components_masked`` — the property-aware form
    ``PropGraph.components`` exposes); kept here so the §I kernel suite
    stays importable from one place."""
    from repro.traverse import components_masked

    return components_masked(g, max_iters=max_iters)


def pagerank(
    g: DIGraph,
    *,
    damping: float = 0.85,
    iters: int = 20,
    edge_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Power iteration over the DI edge list; dangling mass redistributed.
    ``edge_mask`` composes with property queries for typed-edge PageRank.

    Thin alias for the frontier engine's (+, ×) semiring instance
    (``repro.traverse.pagerank_masked`` with no vertex filter), which is
    regression-pinned against the original standalone iteration this
    module used to carry — same formula, one implementation; the relax
    scatter fuses differently than the old ``segment_sum``, so parity is
    one f32 ulp per step, not bitwise (tests/test_semiring.py)."""
    from repro.traverse import pagerank_masked

    return pagerank_masked(
        g, None, edge_mask, damping=damping, iters=iters)


@partial(jax.jit, static_argnames=("max_deg",))
def triangle_count(g: DIGraph, *, max_deg: int) -> jax.Array:
    """Edge-centric triangle counting via sorted-adjacency intersection.

    For each edge (u,v): |N(u) ∩ N(v)| using the DI invariant that both
    adjacency slices are sorted — a merge-free membership test via vectorized
    binary search, padded to ``max_deg``.  Counts each triangle once per
    directed closing wedge; for the undirected count on a symmetrized graph
    divide by 6.
    """
    lane = jnp.arange(max_deg, dtype=jnp.int32)

    start_u = g.seg[g.src]
    deg_u = g.seg[g.src + 1] - start_u
    idx = jnp.clip(start_u[:, None] + lane[None, :], 0, max(g.m - 1, 0))
    nbr_u = g.dst[idx]  # (m, max_deg)
    valid_u = lane[None, :] < deg_u[:, None]

    # membership of nbr_u in N(v) via binary search in v's sorted slice
    lo = g.seg[g.dst][:, None].astype(jnp.int32) * jnp.ones((1, max_deg), jnp.int32)
    hi = g.seg[g.dst + 1][:, None] * jnp.ones((1, max_deg), jnp.int32)
    tgt = nbr_u

    def step(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        go_right = (g.dst[jnp.clip(mid, 0, max(g.m - 1, 0))] < tgt) & (lo < hi)
        return jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid)

    import numpy as _np

    trips = max(1, int(_np.ceil(_np.log2(max(g.m, 2)))) + 1)
    lo, hi = jax.lax.fori_loop(0, trips, step, (lo, hi))
    pos = jnp.clip(lo, 0, max(g.m - 1, 0))
    found = (lo < g.seg[g.dst + 1][:, None]) & (g.dst[pos] == tgt) & valid_u
    return jnp.sum(found.astype(jnp.int64) if False else found.astype(jnp.int32))


@partial(jax.jit, static_argnames=("n_bins",))
def degree_histogram(g: DIGraph, *, n_bins: int = 64) -> jax.Array:
    """Out-degree histogram (Tab. I statistics support)."""
    deg = g.seg[1:] - g.seg[:-1]
    return jnp.bincount(jnp.clip(deg, 0, n_bins - 1), length=n_bins)
