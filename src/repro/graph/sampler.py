"""Neighbor sampling over DI — the real sampler required by ``minibatch_lg``.

GraphSAGE-style layered fanout sampling (e.g. 15-10): starting from a seed
batch, sample up to ``fanout[l]`` in-neighbors per frontier node per layer,
emitting one bipartite block per layer.  The DI structure makes the inner
gather an offset lookup + contiguous slice (``SEG``/``DST``), exactly the
paper's neighborhood access path.

Sampling runs on-device (static shapes, jittable) so the data pipeline can
be pipelined with training; padded slots are masked (edge weight 0 → no
message).  Blocks are emitted with *local* (re-normalized) ids so
downstream layers operate on compact arrays, as production GNN systems do.

Selection is uniform WITHOUT replacement over the (optionally packed-mask
filtered) adjacency — the ``kernels/neighbor_sample`` window-priority core
(docs/ARCHITECTURE.md §15): degree-0 seeds come out fully masked, and
degree ≤ fanout keeps every allowed edge exactly once.  Per-layer PRNG
keys are derived with ``jax.random.fold_in(key, layer)`` — NOT by
splitting and reusing the caller's key — so layers are independent no
matter what key callers pass, and layer l's draw doesn't shift when other
layers are added or removed.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.di import DIGraph
from repro.kernels.neighbor_sample.ops import _window_select, bucketed_window

__all__ = ["SampledBlock", "sample_block", "sample_layers", "block_shapes",
           "layer_key", "layer_keys_batch"]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src_nodes", "dst_nodes", "edge_src", "edge_dst", "edge_mask"],
    meta_fields=["n_src", "n_dst", "n_edges"],
)
@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One bipartite message-flow block (layer) of a sampled minibatch.

    src_nodes: (n_src,) global ids feeding this layer (dst_nodes ∪ sampled nbrs)
    dst_nodes: (n_dst,) global ids updated by this layer
    edge_src/edge_dst: (n_edges,) *local* indices into src_nodes/dst_nodes
    edge_mask: (n_edges,) bool — False for padded sample slots

    Fields are HOST (numpy) arrays: block assembly is host-side compaction
    and every serving consumer (wire framing, renumbering, caching) reads
    them on the host, so eager device puts here would be pure dispatch
    overhead on the QPS path.  The dataclass is still a registered pytree —
    pass a block into jit and the leaves convert on entry.
    """

    src_nodes: np.ndarray
    dst_nodes: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    n_src: int
    n_dst: int
    n_edges: int


@jax.jit
def layer_key(seed, layer) -> jax.Array:
    """``fold_in(PRNGKey(seed), layer)`` as ONE compiled dispatch.

    The eager two-dispatch form costs ~300µs of host overhead per request
    on the serving path; this is the same computation jitted, so the
    resulting key is bitwise the eager one (pinned by tests)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), layer)


# (R,) seed scalars → (R, 2) layer-l keys in one dispatch — the service
# builds a coalesced group's per-row keys with this.  vmap of the same
# scalar computation: row r equals layer_key(seed[r], layer) bitwise.
layer_keys_batch = jax.jit(jax.vmap(layer_key, in_axes=(0, None)))


@partial(jax.jit, static_argnames=("fanout",))
def sample_block(
    g: DIGraph, seeds: jax.Array, key: jax.Array, *, fanout: int,
    edge_words: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sample ≤ fanout out-neighbors per seed, uniform WITHOUT replacement
    over the adjacency slice (filtered by the packed ``edge_words`` bitmap
    when given).  Returns (neighbors, mask), both (len(seeds), fanout);
    masked slots hold -1.  Degree-0 seeds are fully masked; degree ≤
    fanout yields every (allowed) neighbor exactly once."""
    window = bucketed_window(max(g.max_deg, fanout))
    u = jax.random.uniform(key, (seeds.shape[0], window))
    valid = jnp.ones((seeds.shape[0],), bool)
    nbrs, _eids, mask = _window_select(
        g.seg, g.dst, g.m, g.n, seeds, valid, edge_words, u, fanout)
    return nbrs, mask


def local_block(dst_nodes: np.ndarray, src_nodes: np.ndarray,
                nbrs: np.ndarray, mask: np.ndarray) -> SampledBlock:
    """Renumber one layer's (dst_nodes, sampled nbrs) into a local-id
    bipartite block.  ``src_nodes`` must be sorted unique and contain every
    unmasked neighbor; renumbering is by binary search, so local ids are a
    pure function of the global id sets — stable across runs and identical
    however the sample was produced (host loop or fused service path)."""
    pos = np.searchsorted(src_nodes, nbrs.ravel())
    pos = np.clip(pos, 0, max(len(src_nodes) - 1, 0))
    ok = (src_nodes[pos] == nbrs.ravel()) & mask.ravel()
    edge_src = np.where(ok, pos, 0).astype(np.int32)
    edge_dst = np.repeat(
        np.arange(len(dst_nodes), dtype=np.int32), nbrs.shape[1])
    return SampledBlock(
        src_nodes=np.asarray(src_nodes),
        dst_nodes=np.asarray(dst_nodes),
        edge_src=edge_src,
        edge_dst=edge_dst,
        edge_mask=ok,
        n_src=int(len(src_nodes)),
        n_dst=int(len(dst_nodes)),
        n_edges=int(edge_src.shape[0]),
    )


def sample_layers(
    g: DIGraph, seeds: np.ndarray, fanouts: Sequence[int], *, seed: int = 0,
    key: Optional[jax.Array] = None,
    edge_words: Optional[jax.Array] = None,
) -> List[SampledBlock]:
    """Multi-layer fanout sampling (innermost layer first, GraphSAGE order).

    Host-driven compaction between layers (unique) keeps block sizes tight;
    per-layer device sampling stays jitted.  Layer l's key is
    ``fold_in(base, l)`` (module docstring).  Returns blocks ordered for a
    forward pass: blocks[0] aggregates the widest frontier.
    """
    base = jax.random.PRNGKey(seed) if key is None else key
    frontier = np.asarray(seeds, np.int32)
    layer_frontiers = [frontier]
    layer_samples = []
    for li, f in enumerate(fanouts):
        sub = jax.random.fold_in(base, li)
        nbrs, mask = sample_block(
            g, jnp.asarray(frontier), sub, fanout=int(f),
            edge_words=edge_words)
        nbrs_np, mask_np = np.asarray(nbrs), np.asarray(mask)
        layer_samples.append((frontier, nbrs_np, mask_np))
        nxt = np.unique(np.concatenate([frontier, nbrs_np[mask_np]]))
        layer_frontiers.append(nxt.astype(np.int32))
        frontier = layer_frontiers[-1]

    blocks: List[SampledBlock] = []
    for li in range(len(fanouts) - 1, -1, -1):
        dst_nodes, nbrs_np, mask_np = layer_samples[li]
        src_nodes = layer_frontiers[li + 1]
        blocks.append(local_block(dst_nodes, src_nodes, nbrs_np, mask_np))
    return blocks


def block_shapes(batch_nodes: int, fanouts: Sequence[int]) -> List[Tuple[int, int, int]]:
    """Static worst-case (n_src, n_dst, n_edges) per block, innermost-first —
    used by ``input_specs`` for the dry-run (padded dense blocks)."""
    sizes = [batch_nodes]
    for f in fanouts:
        sizes.append(sizes[-1] * (f + 1))  # dst ∪ sampled
    shapes = []
    for li in range(len(fanouts) - 1, -1, -1):
        n_dst = sizes[li]
        n_src = sizes[li + 1]
        shapes.append((n_src, n_dst, n_dst * fanouts[li]))
    return shapes
