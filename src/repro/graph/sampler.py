"""Neighbor sampling over DI — the real sampler required by ``minibatch_lg``.

GraphSAGE-style layered fanout sampling (e.g. 15-10): starting from a seed
batch, sample up to ``fanout[l]`` in-neighbors per frontier node per layer,
emitting one bipartite block per layer.  The DI structure makes the inner
gather an offset lookup + contiguous slice (``SEG``/``DST``), exactly the
paper's neighborhood access path.

Sampling runs on-device (static shapes, jittable) so the data pipeline can be
pipelined with training; padded slots are masked (edge weight 0 → no message).
Blocks are emitted with *local* (re-normalized) ids so downstream layers
operate on compact arrays, as production GNN systems do.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.di import DIGraph

__all__ = ["SampledBlock", "sample_block", "sample_layers", "block_shapes"]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src_nodes", "dst_nodes", "edge_src", "edge_dst", "edge_mask"],
    meta_fields=["n_src", "n_dst", "n_edges"],
)
@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One bipartite message-flow block (layer) of a sampled minibatch.

    src_nodes: (n_src,) global ids feeding this layer (dst_nodes ∪ sampled nbrs)
    dst_nodes: (n_dst,) global ids updated by this layer
    edge_src/edge_dst: (n_edges,) *local* indices into src_nodes/dst_nodes
    edge_mask: (n_edges,) bool — False for padded sample slots
    """

    src_nodes: jax.Array
    dst_nodes: jax.Array
    edge_src: jax.Array
    edge_dst: jax.Array
    edge_mask: jax.Array
    n_src: int
    n_dst: int
    n_edges: int


@partial(jax.jit, static_argnames=("fanout",))
def sample_block(
    g: DIGraph, seeds: jax.Array, key: jax.Array, *, fanout: int
) -> Tuple[jax.Array, jax.Array]:
    """Sample ≤ fanout out-neighbors per seed.  Returns (neighbors, mask),
    both (len(seeds), fanout).  With replacement when degree > fanout is
    sampled (uniform over the adjacency slice), without duplicates otherwise
    is NOT guaranteed — matching GraphSAGE's uniform-with-replacement."""
    start = g.seg[seeds]
    deg = g.seg[seeds + 1] - start
    u = jax.random.uniform(key, (seeds.shape[0], fanout))
    offs = jnp.floor(u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    idx = jnp.clip(start[:, None] + offs, 0, max(g.m - 1, 0))
    mask = (deg > 0)[:, None] & jnp.ones((1, fanout), jnp.bool_)
    nbrs = jnp.where(mask, g.dst[idx], 0)
    return nbrs, mask


def sample_layers(
    g: DIGraph, seeds: np.ndarray, fanouts: Sequence[int], *, seed: int = 0
) -> List[SampledBlock]:
    """Multi-layer fanout sampling (innermost layer first, GraphSAGE order).

    Host-driven compaction between layers (unique) keeps block sizes tight;
    per-layer device sampling stays jitted.  Returns blocks ordered for a
    forward pass: blocks[0] aggregates the widest frontier.
    """
    key = jax.random.PRNGKey(seed)
    frontier = np.asarray(seeds, np.int32)
    layer_frontiers = [frontier]
    layer_samples = []
    for li, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        nbrs, mask = sample_block(g, jnp.asarray(frontier), sub, fanout=int(f))
        nbrs_np, mask_np = np.asarray(nbrs), np.asarray(mask)
        layer_samples.append((frontier, nbrs_np, mask_np))
        nxt = np.unique(np.concatenate([frontier, nbrs_np[mask_np]]))
        layer_frontiers.append(nxt.astype(np.int32))
        frontier = layer_frontiers[-1]

    blocks: List[SampledBlock] = []
    for li in range(len(fanouts) - 1, -1, -1):
        dst_nodes, nbrs_np, mask_np = layer_samples[li]
        src_nodes = layer_frontiers[li + 1]
        # local ids
        pos = np.searchsorted(src_nodes, nbrs_np.ravel())
        pos = np.clip(pos, 0, len(src_nodes) - 1)
        ok = (src_nodes[pos] == nbrs_np.ravel()) & mask_np.ravel()
        edge_src = np.where(ok, pos, 0).astype(np.int32)
        edge_dst = np.repeat(np.arange(len(dst_nodes), dtype=np.int32), nbrs_np.shape[1])
        blocks.append(
            SampledBlock(
                src_nodes=jnp.asarray(src_nodes),
                dst_nodes=jnp.asarray(dst_nodes),
                edge_src=jnp.asarray(edge_src),
                edge_dst=jnp.asarray(edge_dst),
                edge_mask=jnp.asarray(ok),
                n_src=int(len(src_nodes)),
                n_dst=int(len(dst_nodes)),
                n_edges=int(edge_src.shape[0]),
            )
        )
    return blocks


def block_shapes(batch_nodes: int, fanouts: Sequence[int]) -> List[Tuple[int, int, int]]:
    """Static worst-case (n_src, n_dst, n_edges) per block, innermost-first —
    used by ``input_specs`` for the dry-run (padded dense blocks)."""
    sizes = [batch_nodes]
    for f in fanouts:
        sizes.append(sizes[-1] * (f + 1))  # dst ∪ sampled
    shapes = []
    for li in range(len(fanouts) - 1, -1, -1):
        n_dst = sizes[li]
        n_src = sizes[li + 1]
        shapes.append((n_src, n_dst, n_dst * fanouts[li]))
    return shapes
