"""Step-function factories per (family, kind) — shared by dryrun/train/serve.

Each factory returns ``(step_fn, make_abstract_args, in_specs, out_specs)``
where abstract args are ShapeDtypeStruct pytrees (params/opt-state via
``jax.eval_shape`` — nothing is allocated) and specs are PartitionSpec trees
aligned with the arg pytrees.  Training steps include the full AdamW update —
the lowered artifact carries the real memory/collective picture (master
weights + both moments + gradient reduction), not a forward-only toy.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common
from repro.launch import sharding as shard_rules
from repro.launch.mesh import dp_axes
from repro.optim import AdamWConfig, apply_updates, init_state

__all__ = ["build_cell"]


def _cast_float_sds(tree, dtype):
    """Re-dtype float leaves of an SDS tree (serving uses bf16 weights)."""
    def f(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dtype, sharding=getattr(x, "sharding", None))
        return x
    return jax.tree.map(f, tree)


def _metric_specs():
    return None  # replicated scalars


# --------------------------------------------------------------------- LM
def _lm_cell(arch_mod, cfg, kind: str, specs, mesh):
    from repro.models import transformer as T

    opt_cfg = AdamWConfig()
    # Training always FSDPs (master weights + moments dwarf HBM otherwise).
    # Serving keeps weights TP-sharded and DP-replicated when they fit
    # (no per-layer all-gathers on the decode path); the big archs
    # (>8 GiB/chip at TP-16 in bf16) shard the non-TP dim over dp as well.
    serve_bytes_per_chip = cfg.n_params * 2 / mesh.shape["model"]
    fsdp = kind == "train" or serve_bytes_per_chip > 8e9
    if kind in ("train", "prefill"):
        # Megatron SP on the inter-block carry (remat storage /= |model|)
        cfg = dataclasses.replace(cfg, seq_shard_axis="model",
                                  batch_shard_axes=tuple(dp_axes(mesh)))
    if cfg.n_experts:
        # grouped MoE dispatch: one group per dp shard + constraint axes.
        # When E < |model|, split experts into F-slice virtual experts so the
        # expert dim divides the model axis (pure EP — no xb-grad all-reduce).
        dp = dp_axes(mesh)
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        m = mesh.shape["model"]
        split = 1
        if cfg.n_experts % m != 0 and m % cfg.n_experts == 0 \
                and cfg.d_ff % (m // cfg.n_experts) == 0:
            split = m // cfg.n_experts
        e_div = (cfg.n_experts * split) % m == 0
        # decode steps route T = batch tokens; groups must divide T (B=1
        # long-context decode ⇒ a single dispatch group)
        import math as _math
        n_tokens = specs["tokens"].shape[0] if kind == "decode" else n_dp
        groups = _math.gcd(n_dp, n_tokens) if kind == "decode" else n_dp
        cfg = dataclasses.replace(
            cfg, moe_groups=groups, moe_dp_axes=tuple(dp), moe_virtual_split=split,
            moe_expert_axis="model" if e_div else None,
            moe_tp_axis=None if e_div else "model")
    p_specs = shard_rules.lm_param_specs(cfg, mesh, fsdp=fsdp)
    params_sds = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))

    if kind == "train":
        opt_sds = jax.eval_shape(lambda: init_state(params_sds))
        o_specs = shard_rules.opt_state_specs(p_specs)
        b_specs = shard_rules.lm_batch_specs(mesh)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(T.loss_fn)(
                params, batch["tokens"], batch["labels"], cfg)
            params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
            return params, opt_state, {"loss": loss, **metrics}

        return (train_step, (params_sds, opt_sds, specs),
                (p_specs, o_specs, b_specs), (p_specs, o_specs, _metric_specs()))

    params_bf16 = _cast_float_sds(params_sds, jnp.bfloat16)
    if kind == "prefill":
        def prefill_step(params, batch):
            return T.prefill(params, batch["tokens"], cfg)

        return (prefill_step, (params_bf16, specs),
                (p_specs, {"tokens": P(dp_axes(mesh), None)}), None)

    # decode
    B = specs["tokens"].shape[0]
    cache_sds = specs["cache"]
    max_len = max(c["k"].shape[2] for k, c in cache_sds.items() if k != "cur")
    c_specs = shard_rules.lm_cache_specs(cfg, mesh, B, max_len)

    def serve_step(params, cache, tokens):
        return T.decode_step(params, cache, tokens, cfg)

    return (serve_step, (params_bf16, cache_sds, specs["tokens"]),
            (p_specs, c_specs, P(dp_axes(mesh) if B >= 16 else None, None)),
            (None, c_specs))


# -------------------------------------------------------------------- GNN
def _gnn_cell(arch_mod, cfg, kind: str, specs, mesh):
    model_name = arch_mod.MODEL
    opt_cfg = AdamWConfig(lr=1e-3)

    if model_name == "graphcast":
        from repro.models import graphcast as M
        loss = M.loss_fn
        cfg = dataclasses.replace(cfg, dp_axes=tuple(dp_axes(mesh)), tp_axis="model")
        b_specs = shard_rules.gc_batch_specs(mesh, specs)
    else:
        from repro.models import dimenet, gcn, mace
        M = {"gcn": gcn, "mace": mace, "dimenet": dimenet}[model_name]
        loss = M.loss_fn
        b_specs = shard_rules.gnn_batch_specs(mesh, specs)

    params_sds = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = shard_rules.gnn_param_specs(params_sds, mesh)
    opt_sds = jax.eval_shape(lambda: init_state(params_sds))
    o_specs = {"m": p_specs, "v": p_specs, "count": P()}

    def train_step(params, opt_state, batch):
        l, grads = jax.value_and_grad(loss)(params, batch, cfg)
        params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": l, **metrics}

    return (train_step, (params_sds, opt_sds, specs),
            (p_specs, o_specs, b_specs), (p_specs, o_specs, _metric_specs()))


# ------------------------------------------------------------------- DLRM
def _recsys_cell(arch_mod, cfg, kind: str, specs, mesh):
    from repro.models import dlrm as M

    opt_cfg = AdamWConfig(lr=1e-3)
    params_sds = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = shard_rules.dlrm_param_specs(mesh)
    dp = dp_axes(mesh)

    if kind == "train":
        opt_sds = jax.eval_shape(lambda: init_state(params_sds))
        o_specs = shard_rules.opt_state_specs(p_specs)
        b_specs = shard_rules.dlrm_batch_specs(mesh)

        def train_step(params, opt_state, batch):
            l, grads = jax.value_and_grad(M.loss_fn)(
                params, batch["dense"], batch["sparse"], batch["labels"], cfg)
            params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
            return params, opt_state, {"loss": l, **metrics}

        return (train_step, (params_sds, opt_sds, specs),
                (p_specs, o_specs, b_specs), (p_specs, o_specs, _metric_specs()))

    params_bf16 = _cast_float_sds(params_sds, jnp.bfloat16)
    if kind == "retrieval":
        def retrieval_step(params, batch):
            return M.retrieval_scores(params, batch["dense"], batch["sparse"],
                                      batch["candidates"], cfg)

        b_specs = {"dense": P(None, None), "sparse": P(None, None, None),
                   "candidates": P(dp + ("model",), None)}
        return (retrieval_step, (params_bf16, specs), (p_specs, b_specs), None)

    def serve_step(params, batch):
        return M.forward(params, batch["dense"], batch["sparse"], cfg)

    b_specs = {"dense": P(dp, None), "sparse": P(dp, None, None)}
    return (serve_step, (params_bf16, specs), (p_specs, b_specs), P(dp))


def build_cell(arch_id: str, shape_name: str, mesh):
    """Resolve one dry-run cell: returns None for skipped cells, else
    (kind, step_fn, abstract_args, in_specs, out_specs, cfg)."""
    from repro.configs.registry import cell_specs, get_arch

    kind, specs, cfg = cell_specs(arch_id, shape_name)
    if kind is None:
        return None
    mod = get_arch(arch_id)
    fam = mod.FAMILY
    builder = {"lm": _lm_cell, "gnn": _gnn_cell, "recsys": _recsys_cell}[fam]
    step_fn, args, in_specs, out_specs = builder(mod, cfg, kind, specs, mesh)
    return kind, step_fn, args, in_specs, out_specs, cfg
