"""Post-compile HLO analysis: roofline terms with correct while-loop accounting.

``compiled.cost_analysis()`` counts a ``while`` body ONCE — a scanned 80-layer
model reports 1-layer FLOPs (verified empirically; see EXPERIMENTS.md §Dry-run
notes).  This module re-derives the three roofline inputs from the compiled
HLO text with loop-tree multiplication:

  * **flops** — 2·M·N·K per ``dot`` (per-dtype: bf16 vs f32 MXU paths),
    multiplied through the while tree.  Dots dominate every assigned arch;
    elementwise VPU flops are excluded (recorded as a known underestimate).
  * **hbm bytes** — post-fusion traffic proxy: Σ over top-level ops of
    (operand bytes + output bytes).  Fusion internals are invisible by
    construction, which is exactly the HBM-traffic view (VMEM-resident
    intermediates don't count).
  * **collective bytes** — per-chip ring-model traffic per op kind.

Trip counts come from the loop condition's comparison constant (jax scans
lower to ``while`` with a 0-based induction variable).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "Totals"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*\(.*\)\s*->.*\{")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _parse_dims(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",")] if dims else []


def _shape_info(segment: str) -> List[Tuple[str, List[int]]]:
    return [(dt, _parse_dims(dims)) for dt, dims in _SHAPE_RE.findall(segment)]


def _bytes_of(segment: str) -> int:
    total = 0
    for dt, dims in _shape_info(segment):
        if dt in _DTYPE_BYTES:
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES[dt]
    return total


class Totals(dict):
    """{'flops', 'flops_bf16', 'bytes', 'coll_bytes', 'coll_by_kind', 'coll_count'}"""


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    buf: List[str] = []
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                buf = []
        else:
            if line.strip() == "}":
                comps[cur] = buf
                cur = None
            else:
                buf.append(line)
    return comps


def _analyze_comp(lines: List[str]):
    """One computation: local costs + (trip-multiplied) sub-loops deferred."""
    shapes: Dict[str, str] = {}
    local = {"flops": 0.0, "flops_bf16": 0.0, "bytes": 0.0, "param_bytes": 0.0,
             "coll_bytes": 0.0, "coll_by_kind": {}, "coll_count": {}}
    whiles: List[Tuple[str, str]] = []  # (cond, body)
    max_const = 0

    for raw in lines:
        ls = raw.strip()
        m = _DEF_RE.match(ls)
        if not m:
            c = _CONST_RE.search(ls)
            if c:
                max_const = max(max_const, int(c.group(1)))
            continue
        name, shape_seg, op, rest = m.groups()
        shapes[name] = shape_seg
        c = _CONST_RE.search(ls)
        if c:
            max_const = max(max_const, int(c.group(1)))
        if op == "parameter":
            local["param_bytes"] += _bytes_of(shape_seg)
        if op in _SKIP_OPS:
            continue

        out_bytes = _bytes_of(shape_seg)

        if op == "while":
            w = _WHILE_RE.search(rest)
            if w:
                whiles.append((w.group(1), w.group(2)))
            continue

        # HBM traffic proxy — WRITE-SIDE accounting: each op contributes its
        # output bytes (doubled: every written byte is read back by a
        # consumer; entry arguments are added once by analyze_hlo).  Operand
        # bytes are NOT summed at call sites: post-fusion operands are often
        # sliced/windowed inside the fusion (a transpose+slice fusion whose
        # operand is a full scanned KV cache reads only one layer), so
        # operand-side counting inflated a cache decode ~30× (measured; §Perf
        # log).  Corrections:
        #   * (dynamic-)update-slice writes only the update region; every
        #     operand ≥ out/2 is an aliased buffer (XLA in-place), excluded.
        #   * pure dtype-staging converts (wrapped_convert*) are XLA:CPU
        #     artifacts — CPU has no native bf16 dot and stages through f32;
        #     the TPU MXU consumes bf16 natively, so these are zero-traffic
        #     on the target (documented in EXPERIMENTS.md §Dry-run notes).
        if op == "convert" or name.startswith("wrapped_convert") \
                or "convert_computation" in rest:
            continue
        paren = rest.split("),")[0] if ")," in rest else rest.rstrip(")")
        op_bytes_list = [
            _bytes_of(shapes[ref]) for ref in _OPERAND_RE.findall(paren) if ref in shapes
        ]
        dus_like = "dynamic-update-slice" in name or "dynamic_update_slice" in name \
            or op == "dynamic-update-slice"
        if dus_like and op_bytes_list:
            small = sum(b for b in op_bytes_list if b < out_bytes / 2)
            local["bytes"] += 2.0 * small  # read update + write region
        else:
            local["bytes"] += 2.0 * out_bytes
        del op_bytes_list

        if op == "dot":
            refs = _OPERAND_RE.findall(paren)
            lhs_shape = _shape_info(shapes.get(refs[0], ""))[0] if refs and refs[0] in shapes else None
            cd = _CDIMS_RE.search(rest)
            out_elems = 1
            out_info = _shape_info(shape_seg)
            for _, dims in out_info[:1]:
                for d in dims:
                    out_elems *= d
            k = 1
            if lhs_shape and cd:
                for ci in _parse_dims(cd.group(1)):
                    if ci < len(lhs_shape[1]):
                        k *= lhs_shape[1][ci]
            fl = 2.0 * out_elems * k
            local["flops"] += fl
            dt = out_info[0][0] if out_info else "f32"
            lhs_dt = lhs_shape[0] if lhs_shape else dt
            if "bf16" in (dt, lhs_dt) or "f16" in (dt, lhs_dt):
                local["flops_bf16"] += fl

        if op in _COLLECTIVES or (op.endswith("-start") and op[:-6] in _COLLECTIVES):
            kind = op[:-6] if op.endswith("-start") else op
            g = _GROUPS_RE.search(rest)
            if g:
                p = len(g.group(1).split(","))
            else:
                gi = _GROUPS_IOTA_RE.search(rest)
                p = int(gi.group(2)) if gi else 1
            p = max(p, 1)
            if kind == "all-reduce":
                traffic = 2 * (p - 1) / p * out_bytes
            elif kind in ("all-gather", "all-to-all"):
                traffic = (p - 1) / p * out_bytes
            elif kind == "reduce-scatter":
                traffic = (p - 1) * out_bytes
            else:
                traffic = out_bytes
            local["coll_bytes"] += traffic
            local["coll_by_kind"][kind] = local["coll_by_kind"].get(kind, 0.0) + traffic
            local["coll_count"][kind] = local["coll_count"].get(kind, 0) + 1

    return local, whiles, max_const


def analyze_hlo(hlo: str) -> Totals:
    comps = _split_computations(hlo)
    analyzed = {name: _analyze_comp(lines) for name, lines in comps.items()}
    memo: Dict[str, Dict] = {}

    def total(name: str) -> Dict:
        if name in memo:
            return memo[name]
        if name not in analyzed:
            z = {"flops": 0.0, "flops_bf16": 0.0, "bytes": 0.0, "coll_bytes": 0.0,
                 "coll_by_kind": {}, "coll_count": {}}
            memo[name] = z
            return z
        local, whiles, _ = analyzed[name]
        agg = {k: (dict(v) if isinstance(v, dict) else v) for k, v in local.items()}
        for cond, body in whiles:
            trips = analyzed.get(cond, (None, None, 1))[2] or 1
            sub = total(body)
            for k in ("flops", "flops_bf16", "bytes", "coll_bytes"):
                agg[k] += trips * sub[k]
            for k, v in sub["coll_by_kind"].items():
                agg["coll_by_kind"][k] = agg["coll_by_kind"].get(k, 0.0) + trips * v
            for k, v in sub["coll_count"].items():
                agg["coll_count"][k] = agg["coll_count"].get(k, 0) + trips * v
        memo[name] = agg
        return agg

    # (entry arguments are read once from HBM: added below)

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: the computation with the largest local cost
        entry = max(analyzed, key=lambda n: analyzed[n][0]["bytes"]) if analyzed else ""
    out = Totals(total(entry))
    if entry in analyzed:
        out["bytes"] += analyzed[entry][0]["param_bytes"]  # arguments read once
    return out


def top_ops(hlo: str, n: int = 15):
    """Debug view: heaviest ops by trip-multiplied HBM-traffic proxy.
    Returns [(bytes_with_trips, comp, op, line_prefix)]."""
    comps = _split_computations(hlo)
    # trip factor per computation: entry=1; while bodies multiply
    analyzed = {name: _analyze_comp(lines) for name, lines in comps.items()}
    factor = {name: 0 for name in comps}
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry:
        factor[entry] = 1
        frontier = [entry]
        while frontier:
            nxt = []
            for name in frontier:
                _, whiles, _ = analyzed[name]
                for cond, body in whiles:
                    trips = analyzed.get(cond, (None, None, 1))[2] or 1
                    if body in factor:
                        factor[body] += factor[name] * trips
                        nxt.append(body)
            frontier = nxt

    rows = []
    for name, lines in comps.items():
        f = factor.get(name, 0)
        if f == 0:
            continue
        shapes: Dict[str, str] = {}
        for raw in lines:
            m = _DEF_RE.match(raw.strip())
            if not m:
                continue
            nm, shape_seg, op, rest = m.groups()
            shapes[nm] = shape_seg
            if op in _SKIP_OPS or op == "while":
                continue
            paren = rest.split("),")[0] if ")," in rest else rest.rstrip(")")
            ob = _bytes_of(shape_seg)
            opl = [_bytes_of(shapes[r]) for r in _OPERAND_RE.findall(paren) if r in shapes]
            if op == "convert" or nm.startswith("wrapped_convert") \
                    or "convert_computation" in rest:
                continue
            dus_like = "dynamic-update-slice" in nm or "dynamic_update_slice" in nm \
                or op == "dynamic-update-slice"
            if dus_like and opl:
                b = 2.0 * sum(x for x in opl if x < ob / 2)
            else:
                b = 2.0 * ob
            rows.append((b * f, name, op, raw.strip()[:110]))
    rows.sort(reverse=True)
    return rows[:n]
