"""Training launcher — any --arch, any scale, restartable.

On the CPU container this runs REDUCED (smoke) configs end-to-end — real
optimization steps with checkpointing and failure recovery; on a TPU fleet the
same entrypoint runs the full configs (the mesh adapts to jax.device_count()).

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance: the TrainController checkpoints every --ckpt-every steps and
auto-resumes from the newest checkpoint; --fail-at injects a simulated crash
(the loop restarts from the last checkpoint and continues — used by the FT
integration test and the quickstart demo).
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import dlrm_batch, lm_batch, synthetic_gc_batch, synthetic_graph_batch
from repro.ft import FailureInjector, TrainController
from repro.optim import AdamWConfig, apply_updates, init_state

__all__ = ["make_smoke_step", "run_training", "main"]


def make_smoke_step(arch_id: str, *, batch: int, seq: int, seed: int = 0):
    """(init_state_fn, step_fn(state, step) -> (state, metrics)) on the smoke
    config of ``arch_id`` — pure, jittable, deterministic per (seed, step)."""
    from repro.configs.registry import get_arch

    mod = get_arch(arch_id)
    cfg = mod.smoke_config()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=10_000)
    key = jax.random.PRNGKey(seed)

    if mod.FAMILY == "lm":
        from repro.models import transformer as T

        params = T.init_params(key, cfg)

        def loss(p, b):
            return T.loss_fn(p, b["tokens"], b["labels"], cfg)

        def batch_fn(step):
            return lm_batch(step, batch=batch, seq=seq, vocab=cfg.vocab, seed=seed)

    elif mod.FAMILY == "recsys":
        from repro.models import dlrm as M

        params = M.init_params(key, cfg)

        def loss(p, b):
            return M.loss_fn(p, b["dense"], b["sparse"], b["labels"], cfg)

        def batch_fn(step):
            return dlrm_batch(step, batch=batch, vocab=cfg.vocab_size,
                              multi_hot=cfg.multi_hot, seed=seed)

    else:  # gnn
        if mod.MODEL == "graphcast":
            from repro.models import graphcast as M

            params = M.init_params(key, cfg)
            gb = synthetic_gc_batch(n_nodes=128, n_edges=512, n_vars=cfg.n_vars, seed=seed)

            def loss(p, b):
                return M.loss_fn(p, b, cfg)

            def batch_fn(step):
                return gb
        else:
            from repro.models import dimenet, gcn, mace

            M = {"gcn": gcn, "mace": mace, "dimenet": dimenet}[mod.MODEL]
            params = M.init_params(key, cfg)
            if mod.MODEL == "gcn":
                gb = synthetic_graph_batch(n_nodes=128, n_edges=512, d_feat=cfg.d_in,
                                           n_classes=cfg.n_classes, seed=seed)
            else:
                gb = synthetic_graph_batch(
                    n_nodes=64, n_edges=256, with_pos=True,
                    n_species=cfg.n_species, n_graphs=4,
                    with_triplets=(mod.MODEL == "dimenet"), seed=seed)

            def loss(p, b):
                return M.loss_fn(p, b, cfg)

            def batch_fn(step):
                return gb

    @partial(jax.jit, donate_argnums=(0,))
    def jit_step(state, batch_data):
        params, opt = state
        l, grads = jax.value_and_grad(loss)(params, batch_data)
        params, opt, metrics = apply_updates(params, grads, opt, opt_cfg)
        return (params, opt), {"loss": l, **metrics}

    def step_fn(state, step):
        return jit_step(state, batch_fn(step))

    return (params, init_state(params)), step_fn, cfg


def run_training(arch_id: str, *, steps: int, batch: int, seq: int, ckpt_dir: str,
                 ckpt_every: int = 25, fail_at=(), seed: int = 0, log_every: int = 10):
    state, step_fn, cfg = make_smoke_step(arch_id, batch=batch, seq=seq, seed=seed)
    ckpt = CheckpointManager(ckpt_dir, keep=2)
    controller = TrainController(ckpt=ckpt, step_fn=step_fn, ckpt_every=ckpt_every)
    injector = FailureInjector(fail_at) if fail_at else None
    t0 = time.time()
    losses = []

    def log(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % log_every == 0:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  ({time.time()-t0:.1f}s)")

    state = controller.run(state, steps, injector=injector, log=log)
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _, losses = run_training(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        fail_at=tuple(args.fail_at), seed=args.seed)
    print(f"done: {len(losses)} steps, loss {losses[0]:.4f} → {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
