"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be executed as a script/module entry — the first two lines pin 512
placeholder host devices BEFORE jax initializes.  Never import this module's
XLA_FLAGS side effect from tests/benches (they want 1 device).

Per cell it records: memory_analysis (per-device bytes — proves it fits),
cost_analysis (FLOPs/bytes), and the collective traffic parsed from the
compiled HLO — the three §Roofline terms derive from these
(benchmarks/roofline.py consumes the JSON this writes).
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402


# ------------------------------------------------------------------ dry run
def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             verbose: bool = True) -> Optional[Dict]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    built = build_cell(arch, shape, mesh)
    if built is None:
        if verbose:
            print(f"[skip] {arch} × {shape}: long_500k on pure full-attention arch")
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod, "skipped": True}
    kind, step_fn, args, in_specs, out_specs, cfg = built

    from repro.launch.sharding import tree_named
    in_sh = tree_named(mesh, in_specs)
    out_sh = tree_named(mesh, out_specs) if out_specs is not None else None

    t0 = time.time()
    with mesh:
        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    tot = analyze_hlo(hlo)  # while-tree-correct flops/bytes/collectives

    rec = {
        "arch": arch, "shape": shape, "kind": kind, "multi_pod": multi_pod,
        "mesh": dict(zip(mesh.axis_names, [int(s) for s in mesh.devices.shape])),
        "n_devices": int(np.prod(mesh.devices.shape)),
        # analyzer totals are PER DEVICE (the compiled module is the per-device
        # program under GSPMD)
        "flops_per_dev": float(tot["flops"]),
        "flops_bf16_per_dev": float(tot["flops_bf16"]),
        "hbm_bytes_per_dev": float(tot["bytes"]),
        "coll_bytes_per_dev": float(tot["coll_bytes"]),
        "coll_by_kind": {k: float(v) for k, v in tot["coll_by_kind"].items()},
        "coll_count": {k: int(v) for k, v in tot["coll_count"].items()},
        # raw cost_analysis kept for reference (no loop multiplication)
        "xla_flops_raw": float(cost.get("flops", 0.0)) if cost else 0.0,
        "xla_bytes_raw": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "skipped": False,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
    if verbose:
        per_dev = rec.get("temp_size_in_bytes", 0) + rec.get("argument_size_in_bytes", 0)
        print(f"[ok] {arch} × {shape} ({kind}, {'2-pod' if multi_pod else '1-pod'}): "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"flops/dev={rec['flops_per_dev']:.3e} hbm/dev={rec['hbm_bytes_per_dev']:.3e} "
              f"coll/dev={rec['coll_bytes_per_dev']:.3e}B | args+temp/dev={per_dev/2**30:.2f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=str, default="artifacts/dryrun.json")
    args = ap.parse_args()

    from repro.configs.registry import list_cells

    cells = []
    if args.all:
        cells = [(a, s) for a, s, _ in list_cells()]
    elif args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    elif args.arch:
        from repro.configs.registry import arch_shapes
        cells = [(args.arch, s) for s in arch_shapes(args.arch)]
    else:
        ap.error("--all or --arch [--shape] required")

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    records = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            records = json.load(f)
    done = {(r["arch"], r["shape"], r["multi_pod"]) for r in records}

    failures = []
    for mp in meshes:
        for a, s in cells:
            if (a, s, mp) in done:
                print(f"[cached] {a} × {s} multi_pod={mp}")
                continue
            try:
                rec = run_cell(a, s, multi_pod=mp)
                if rec is not None:
                    records.append(rec)
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((a, s, mp, str(e)[:200]))
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells)}×{len(meshes)} cells ok → {args.out}")


if __name__ == "__main__":
    main()
