"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests and benches see 1 CPU device;
only dryrun.py sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before first jax init.

Topology: single pod = 16×16 = 256 chips (v5e pod), axes ("data", "model");
multi-pod = 2×16×16 = 512 chips, axes ("pod", "data", "model").  The ``model``
axis carries ICI-bandwidth-hungry collectives (TP/EP) and never crosses pods;
``pod`` composes with ``data`` for batch/entity parallelism so only gradient /
mask all-reduces traverse the inter-pod links (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Tuple

import jax

__all__ = ["make_production_mesh", "dp_axes", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh):
    """The pure-data-parallel axis group: ('pod','data') when multi-pod."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
