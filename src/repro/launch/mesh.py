"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests and benches see 1 CPU device;
only dryrun.py sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before first jax init.

Topology: single pod = 16×16 = 256 chips (v5e pod), axes ("data", "model");
multi-pod = 2×16×16 = 512 chips, axes ("pod", "data", "model").  The ``model``
axis carries ICI-bandwidth-hungry collectives (TP/EP) and never crosses pods;
``pod`` composes with ``data`` for batch/entity parallelism so only gradient /
mask all-reduces traverse the inter-pod links (docs/ARCHITECTURE.md §5).

``make_entity_mesh`` is the property-graph entry point: a 1-D ``("data",)``
mesh over the first P local devices, the "P locales" of the paper's O(NK/P)
cost model.  CPU test/bench runs get P > 1 via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_entity_mesh", "dp_axes", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_entity_mesh(n_devices: Optional[int] = None):
    """1-D ``("data",)`` mesh over ``n_devices`` local devices (default: all).

    The property-graph stores shard their entity axis over this mesh
    (``launch.sharding.pg_specs``); a sub-mesh (``n_devices < len(devices)``)
    is how bench_shard.py sweeps the locale count 1→8 inside one process.
    """
    devs = jax.devices()
    p = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= p <= len(devs):
        raise ValueError(f"n_devices={p} not in [1, {len(devs)}]")
    return jax.sharding.Mesh(np.array(devs[:p]), ("data",))


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh):
    """The pure-data-parallel axis group: ('pod','data') when multi-pod."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
