"""Sharding rules: PartitionSpec trees per architecture family.

One place owns the mesh-axis assignment policy (docs/ARCHITECTURE.md §5):

  * LM params — Megatron TP over ``model`` (head dim / FFN hidden / vocab),
    optional FSDP over ``data`` on the non-TP weight dim (the big archs);
    scanned group leaves carry a leading n_groups dim that stays unsharded.
  * MoE experts — expert dim over ``model`` when divisible (DBRX: 16e/16-way),
    otherwise expert-TP on the FFN hidden dim (Mixtral: 8e ⇒ F over model).
  * Graph/property-graph — entities/edges over ``(pod, data)`` (the paper's
    block distribution), wide feature dims over ``model``.
  * DLRM — table rows over ``model``, batch over ``(pod, data)``.

GSPMD tolerates non-divisible shardings (it pads), so rules only special-case
divisibility where the padding would be pathological (KV heads).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes

__all__ = [
    "lm_param_specs", "lm_batch_specs", "lm_cache_specs", "opt_state_specs",
    "gnn_batch_specs", "gnn_param_specs", "gc_batch_specs", "dlrm_param_specs",
    "dlrm_batch_specs", "named", "tree_named",
    "pg_entity_axes", "pg_entity_shards", "pg_di_specs", "pg_arr_specs",
    "pg_list_specs", "pg_listd_specs", "pg_prop_spec", "pg_specs",
    "pg_word_pad",
]


def named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ------------------------------------------------------------------------- LM
def lm_param_specs(cfg, mesh, *, fsdp: bool = False) -> Dict:
    """Spec tree matching models.transformer.init_params structure."""
    dp = dp_axes(mesh)
    fa = dp if fsdp else None  # FSDP axis group for the non-TP dim

    def layer_specs() -> Dict:
        s = {
            "ln1": {"scale": P(None, None)},
            "wq": {"w": P(None, fa, "model")},
            "wk": {"w": P(None, fa, "model")},
            "wv": {"w": P(None, fa, "model")},
            "wo": {"w": P(None, "model", fa)},
            "ln2": {"scale": P(None, None)},
        }
        if cfg.qkv_bias:
            for k in ("wq", "wk", "wv"):
                s[k]["b"] = P(None, "model")
        if cfg.post_norms:
            s["ln1b"] = {"scale": P(None, None)}
            s["ln2b"] = {"scale": P(None, None)}
        if cfg.n_experts:
            n_virtual = cfg.n_experts * getattr(cfg, "moe_virtual_split", 1)
            e_div = n_virtual % mesh.shape["model"] == 0
            if e_div:  # expert parallelism over (virtual) experts
                up = P(None, "model", fa, None)
                down = P(None, "model", None, fa)
            else:      # expert-TP on the hidden dim
                up = P(None, None, fa, "model")
                down = P(None, None, "model", fa)
            s["moe"] = {"router": {"w": P(None, fa, None)}, "up": up, "down": down}
            if cfg.gated:
                s["moe"]["gate"] = up
        else:
            s["mlp"] = {"up": {"w": P(None, fa, "model")},
                        "down": {"w": P(None, "model", fa)}}
            if cfg.gated:
                s["mlp"]["gate"] = {"w": P(None, fa, "model")}
        return s

    specs = {
        "embed": P("model", fa),
        "groups": [layer_specs() for _ in cfg.pattern],
        "final_norm": {"scale": P(None)},
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = {"w": P(fa, "model")}
    return specs


def lm_batch_specs(mesh) -> Dict:
    dp = dp_axes(mesh)
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def lm_cache_specs(cfg, mesh, batch: int, max_len: int) -> Dict:
    """Cache (G, B, S, Hkv, Dh): batch over dp when divisible (else the
    sequence absorbs dp), KV heads over 'model' when divisible — otherwise
    HEAD_DIM absorbs 'model'.  Never shard the dims receiving dynamic-offset
    writes (layer g, seq slot): GSPMD lowers DUS-at-traced-offset into a
    full-buffer masked select per layer per step when the offset dim is
    sharded — a measured ~8× decode-traffic blowup (§Perf log)."""
    dp = dp_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    heads_div = cfg.n_kv_heads % mesh.shape["model"] == 0
    if batch % n_dp == 0:
        b_ax, s_axes = dp, ()
    else:
        b_ax, s_axes = None, dp  # B=1 long-context: sequence takes dp
    h_ax = "model" if heads_div else None
    if not heads_div:
        # seq absorbs 'model': reads are fully local (scores keep the seq dim;
        # softmax reduces with tiny all-reduces); measured best vs head_dim
        # sharding (which all-gathers the cache per layer) — §Perf log.
        s_axes = tuple(s_axes) + ("model",)
    kv = P(None, b_ax, (tuple(s_axes) or None), h_ax, None)
    specs = {}
    for i, _ in enumerate(cfg.pattern):
        specs[f"pos{i}"] = {"k": kv, "v": kv}
    specs["cur"] = P()
    return specs


def opt_state_specs(param_specs) -> Dict:
    """AdamW state mirrors param sharding; count is replicated."""
    return {
        "m": jax.tree.map(lambda s: s, param_specs, is_leaf=lambda x: isinstance(x, P)),
        "v": jax.tree.map(lambda s: s, param_specs, is_leaf=lambda x: isinstance(x, P)),
        "count": P(),
    }


# -------------------------------------------------------------- property graph
def pg_entity_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the entity dimension of the DIP stores shards over — the
    paper's block distribution ("each locale only processes the array chunk
    it owns").  On the production ("data", "model") / ("pod", "data",
    "model") meshes that is the data-parallel axis group; on a bare 1-D mesh
    (``make_entity_mesh``) it is the sole axis."""
    names = mesh.axis_names
    if "data" in names:
        return dp_axes(mesh)
    return (names[0],)


def pg_entity_shards(mesh) -> int:
    """P — the entity shard count (the paper's locale count)."""
    p = 1
    for a in pg_entity_axes(mesh):
        p *= mesh.shape[a]
    return p


def pg_di_specs(mesh) -> Dict[str, P]:
    """DI graph placement: edge arrays block-distributed over entities;
    ``seg`` (n+1 offsets) and ``node_map`` replicated — both are read by
    every shard (offset lookups, original-id translation)."""
    e = P(pg_entity_axes(mesh))
    return {"src": e, "dst": e, "seg": P(), "node_map": P()}


def pg_arr_specs(mesh) -> Dict[str, P]:
    """DIP-ARR: shard the (K, N) bitmap on the ENTITY dim only — the K
    attribute dim (≤ a few hundred) stays resident on every device so any
    attribute-subset query touches exclusively locally-owned entities
    (docs/ARCHITECTURE.md §2/§7).  The bit-packed plane uses the SAME spec
    on its (K, W = ⌈N/32⌉) word axis: entity ownership stays word-aligned
    (every device owns whole uint32 words → 32·W/P whole entities), so a
    word-sharded mask IS an entity-sharded mask (docs/ARCHITECTURE.md §14;
    padding math in ``pg_word_pad``)."""
    return {"bitmap": P(None, pg_entity_axes(mesh))}


def pg_word_pad(mesh, n: int) -> int:
    """Padded WORD count for a bit-packed plane over ``n`` entities:
    smallest positive multiple of the shard count ≥ ⌈n/32⌉.  Each shard
    then owns ``32 · pg_word_pad / P`` entities; pad words (and the tail
    bits of the last real word) are zero by the bitplane invariant, so no
    query path masks them."""
    from repro.core.bitplane import n_words

    p = pg_entity_shards(mesh)
    return max(-(-n_words(n) // p), 1) * p


def pg_list_specs(mesh) -> Dict[str, P]:
    """DIP-LIST CSR: ``val``/``slot_entity`` (nnz-sized, entity-sorted) shard
    over the slot dim — entity-aligned block distribution to within one
    entity's list; ``off`` (n+1) replicated."""
    e = P(pg_entity_axes(mesh))
    return {"off": P(), "val": e, "slot_entity": e}


def pg_listd_specs(mesh) -> Dict[str, P]:
    """DIP-LISTD: only the inverted-CSR query arrays ship to devices — the
    entity list shards over slots, the attribute offsets replicate.  The
    linked-chain arrays (entity/attr/prev/nxt/last_tracker) deliberately
    stay host-side: the pointer chase is sequential (docs/ARCHITECTURE.md
    §2) and has no sharded execution."""
    e = P(pg_entity_axes(mesh))
    return {"a_off": P(), "a_ent": e}


def pg_prop_spec(mesh) -> P:
    """Typed property columns + their valid masks: entity-sharded."""
    return P(pg_entity_axes(mesh))


def pg_specs(mesh) -> Dict[str, Any]:
    """The whole property-graph spec family keyed by structure name."""
    return {
        "di": pg_di_specs(mesh),
        "arr": pg_arr_specs(mesh),
        "list": pg_list_specs(mesh),
        "listd": pg_listd_specs(mesh),
        "prop": pg_prop_spec(mesh),
    }


# ------------------------------------------------------------------------ GNN
def _dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def gnn_batch_specs(mesh, batch) -> Any:
    """GraphBatch-shaped tree of P: entity/edge arrays block-distributed over
    (pod, data) on their leading dim — the paper's DI distribution.  Wide
    feature dims additionally shard over 'model' so the all-gathered gather
    operands GSPMD materializes for message passing stay 1/|model| sized
    (node tables replicate per-device otherwise — measured 181 GiB/dev on
    graphcast × ogb_products; §Perf log).  Tiny leaves (labels of a single
    mega-graph) stay replicated."""
    dp = dp_axes(mesh)
    n_dp = _dp_size(mesh)
    import dataclasses as dc

    fields = {}
    for f in dc.fields(batch):
        if f.name in ("n_nodes", "n_edges", "n_graphs"):
            continue
        leaf = getattr(batch, f.name)
        if leaf is None:
            fields[f.name] = None
            continue
        shape = leaf.shape
        lead = dp if (len(shape) >= 1 and shape[0] % n_dp == 0) else None
        rest = [None] * (len(shape) - 1)
        if len(shape) == 2 and shape[1] >= 64 and shape[1] % mesh.shape["model"] == 0:
            rest[0] = "model"
        fields[f.name] = P(lead, *rest)
    return dc.replace(batch, **fields)


def gnn_param_specs(params, mesh, *, tp_threshold: int = 256) -> Any:
    """Shard the last dim of wide (≥ tp_threshold) 2-D weights over 'model';
    replicate the rest.  §Perf iteration 2 (graphcast) tried full replication
    to kill the (E, d) edge-row all-gathers — REFUTED: the node-grad
    all-reduces it induces are 2.7× larger (1.04e12 vs 3.8e11 B/dev) and
    memory regressed 85→174 GiB.  The (E, d)-scale cross-shard traffic is the
    GSPMD floor for arbitrary-connectivity gathers; going below it needs
    locality-aware edge partitioning + shard_map manual collectives
    (recorded as future work in EXPERIMENTS.md §Perf)."""

    def rule(leaf):
        shape = leaf.shape
        if len(shape) >= 2 and shape[-1] >= tp_threshold:
            return P(*([None] * (len(shape) - 1)), "model")
        return P(*([None] * len(shape)))

    return jax.tree.map(rule, params)


def gc_batch_specs(mesh, batch) -> Any:
    """GCBatch-shaped tree of P (leading-dim block distribution + feature-dim
    'model' sharding for wide arrays, same rationale as gnn_batch_specs)."""
    dp = dp_axes(mesh)
    n_dp = _dp_size(mesh)
    import dataclasses as dc

    fields = {}
    for f in dc.fields(batch):
        if f.name.startswith("n_"):
            continue
        leaf = getattr(batch, f.name)
        shape = leaf.shape
        lead = dp if shape[0] % n_dp == 0 else None
        rest = [None] * (len(shape) - 1)
        if len(shape) == 2 and shape[1] >= 64 and shape[1] % mesh.shape["model"] == 0:
            rest[0] = "model"
        fields[f.name] = P(lead, *rest)
    return dc.replace(batch, **fields)


# ----------------------------------------------------------------------- DLRM
def dlrm_param_specs(mesh) -> Dict:
    return {
        "tables": P(None, "model", None),  # row-sharded vocab per table
        "bot": [{"w": P(None, None), "b": P(None)} for _ in range(3)],
        "top": [{"w": P(None, None), "b": P(None)} for _ in range(3)],
    }


def dlrm_batch_specs(mesh) -> Dict:
    dp = dp_axes(mesh)
    return {"dense": P(dp, None), "sparse": P(dp, None, None), "labels": P(dp)}
