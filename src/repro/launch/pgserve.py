"""pgserve — CLI driver for the graph analytics service (src/repro/service/).

Builds named tenant graphs, generates a synthetic multi-tenant pattern
workload (zipf-skewed over a pattern pool — hot patterns repeat, like real
dashboards), and drives a ``Service`` with closed-loop concurrent clients,
reporting throughput/latency and the service's coalescing/cache counters.

    # throughput report: 2 tenant graphs, 64 requests, 8 concurrent clients
    PYTHONPATH=src python -m repro.launch.pgserve --graphs 2 --requests 64 \
        --concurrency 8

    # CI smoke: correctness across all backends (+ mesh when >1 device)
    PYTHONPATH=src python -m repro.launch.pgserve --smoke

Network mode (the ``pgd`` front-end, docs/ARCHITECTURE.md §9):

    # foreground server process owning the graphs and devices
    PYTHONPATH=src python -m repro.launch.pgserve --serve --port 8945

    # cross-process throughput: spawns the server, drives it with
    # concurrent PGClient connections over TCP
    PYTHONPATH=src python -m repro.launch.pgserve --net --concurrency 8

    # CI smoke: client↔server round-trip bitwise vs in-process match
    PYTHONPATH=src python -m repro.launch.pgserve --net --smoke

The workload/runner helpers here are also the benchmark's building blocks
(``benchmarks/bench_serve.py`` imports them), so the CLI and the benchmark
measure the same thing.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "build_tenant_graph",
    "pattern_pool",
    "synthetic_workload",
    "run_workload",
    "run_workload_net",
    "run_sequential",
    "spawn_server",
    "serve",
    "smoke",
    "net_smoke",
    "main",
]

N_LABELS = 12
RELS = ("follows", "likes")


def build_tenant_graph(backend: str, m: int, *, mesh=None, seed: int = 0):
    """One synthetic tenant: Tab.-I-regime random graph with labels
    ``l0..l{N_LABELS-1}``, relationships ``follows``/``likes``, an ``age``
    vertex property (the attribute shape every pool pattern queries) and a
    ``w`` edge weight in [0.5, 2) — what the weighted analytics traverse."""
    from repro.core import PropGraph
    from repro.graph import random_uniform_graph

    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend, mesh=mesh).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes, rng.choice([f"l{i}" for i in range(N_LABELS)],
                                         size=len(nodes)))
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.add_edge_relationships(nodes[es], nodes[ed],
                              rng.choice(RELS, size=len(es)))
    pg.add_node_properties("age", nodes,
                           rng.integers(0, 90, len(nodes)).astype(np.int32))
    pg.add_edge_properties("w", nodes[es], nodes[ed],
                           rng.uniform(0.5, 2.0, len(es)).astype(np.float32))
    return pg


def pattern_pool() -> List[str]:
    """The query mix: 1-hop label/relationship shapes, predicate filters,
    reverse hops and a 2-hop chain — every planner path gets traffic."""
    return [
        "(a:l1|l2)-[:follows]->(b:l3)",
        "(a:l0)-[:likes]->(b:l4|l5)",
        "(a:l6 {age > 30})-[:follows]->(b)",
        "(a)<-[:likes]-(b:l7|l8)",
        "(a:l9)-[:follows]->(b:l10)",
        "(a:l2|l3 {age <= 60})-[:likes]->(b:l0)",
        "(a:l11)-[:likes]->(b:l1)",
        "(a:l4)-[:follows]->(b)-[:likes]->(c:l5)",
        "(a:l5|l6)-[:follows]->(b:l7)",
        "(a:l8 {age >= 18})-[:likes]->(b:l9|l10)",
        "(a:l3)<-[:follows]-(b:l2)",
        "(a:l0|l1|l2)-[:likes]->(b:l3|l4|l5)",
    ]


def synthetic_workload(
    graph_names: Sequence[str],
    pool: Sequence[str],
    n_requests: int,
    *,
    seed: int = 0,
    skew: float = 1.1,
) -> List[Tuple[str, str]]:
    """(graph, pattern) stream: tenants drawn uniformly, patterns drawn
    zipf-skewed (weight ∝ 1/rank^skew) — a hot head and a long tail, the
    distribution request coalescing and result caching are built for."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
    w = ranks ** -skew
    w /= w.sum()
    return [
        (graph_names[int(rng.integers(len(graph_names)))],
         pool[int(rng.choice(len(pool), p=w))])
        for _ in range(n_requests)
    ]


def _run_closed_loop(make_session, workload: Sequence[Tuple[str, str]],
                     concurrency: int, *, repeats: int = 1) -> Dict[str, float]:
    """The shared closed-loop harness behind ``run_workload`` (in-process)
    and ``run_workload_net`` (TCP): the workload splits round-robin over
    ``concurrency`` client threads; each thread gets its own session from
    ``make_session()`` — ``(call(graph, pattern), close())`` — and issues
    its next request only after the previous one resolved.  Session setup
    runs inside the measured loop on the client's own thread (a real
    client pays its connection cost too).  Returns wall/qps/latency
    metrics.

    ``repeats`` > 1 replays the workload and keeps the best-throughput
    run (latencies from that run) — multithreaded closed loops are highly
    exposed to cgroup CPU-quota throttling and noisy neighbors, and the
    best run is the least-interfered estimate of the service's own cost.
    Replays hit warm caches; measure cold behavior with ``repeats=1`` on
    a fresh ``Service``."""
    if repeats > 1:
        runs = [_run_closed_loop(make_session, workload, concurrency)
                for _ in range(repeats)]
        return max(runs, key=lambda r: r["qps"])
    lat_lock = threading.Lock()
    latencies: List[float] = []
    errors: List[BaseException] = []

    def client(items: List[Tuple[str, str]]) -> None:
        try:
            call, close = make_session()
        except BaseException as e:  # noqa: BLE001 — reported, not raised
            with lat_lock:
                errors.append(e)
            return
        try:
            for graph, pattern in items:
                t0 = time.monotonic()
                try:
                    call(graph, pattern)
                except BaseException as e:  # noqa: BLE001
                    with lat_lock:
                        errors.append(e)
                    return
                with lat_lock:
                    latencies.append(time.monotonic() - t0)
        finally:
            close()

    shards = [list(workload[i::concurrency]) for i in range(concurrency)]
    threads = [threading.Thread(target=client, args=(s,)) for s in shards if s]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    if errors:
        raise errors[0]
    lat = np.sort(np.asarray(latencies))
    return {
        "wall_s": wall,
        "qps": len(workload) / wall,
        "p50_ms": float(lat[len(lat) // 2] * 1e3),
        "p95_ms": float(lat[min(int(len(lat) * 0.95), len(lat) - 1)] * 1e3),
    }


def run_workload(service, workload: Sequence[Tuple[str, str]],
                 concurrency: int, *, repeats: int = 1) -> Dict[str, float]:
    """Closed-loop clients against an in-process ``Service`` (the shared
    harness's docstring has the methodology)."""

    def make_session():
        return (lambda graph, pattern:
                service.submit(graph, pattern).result(timeout=120),
                lambda: None)

    return _run_closed_loop(make_session, workload, concurrency,
                            repeats=repeats)


def warm_serving_path(pg, pool: Sequence[str], *, max_masks: int = 64) -> None:
    """Compile everything steady-state serving will hit: each pattern's
    propagation program (direct match) and the batched store queries at
    every Q bucket ≤ ``max_masks`` — batch composition varies with load,
    and an unvisited bucket would otherwise pay its compile inside a
    measured (or served) window."""
    import jax

    from repro.kernels.bitmap_query.ops import Q_BUCKETS, bucketed_q

    for p in pool:
        jax.block_until_ready(pg.match(p))
    for b in Q_BUCKETS:
        jax.block_until_ready(pg._vstore.query_any_batched([()] * b))
        jax.block_until_ready(pg._estore.query_any_batched([()] * b))
        if b >= bucketed_q(max_masks):
            break


def run_sequential(graphs: Dict[str, object],
                   workload: Sequence[Tuple[str, str]], *,
                   repeats: int = 1) -> Dict[str, float]:
    """The per-request baseline: every request is a cold, single-tenant
    ``PropGraph.match`` call, one after another (no service, no caches, no
    coalescing).  ``repeats`` keeps the best run, like ``run_workload``."""
    import jax

    best = None
    for _ in range(max(repeats, 1)):
        t0 = time.monotonic()
        for graph, pattern in workload:
            jax.block_until_ready(graphs[graph].match(pattern))
        wall = time.monotonic() - t0
        if best is None or wall < best:
            best = wall
    return {"wall_s": best, "qps": len(workload) / best}


# ------------------------------------------------------------- network mode
def serve(*, port: int = 0, host: str = "127.0.0.1", backend: str = "arr",
          backends: Optional[Sequence[str]] = None, graphs: int = 2,
          m: int = 20_000, seed: int = 0, mesh: bool = False,
          warm: bool = False) -> None:
    """Foreground server process: build the tenant graphs, bind, print
    ``PGSERVE LISTENING <port>`` (the spawn handshake), serve until a
    client sends ``shutdown``.

    ``backends`` (e.g. ``("arr", "list", "listd")``) builds ONE graph per
    backend, named after it — the multi-backend smoke layout; otherwise
    ``graphs`` tenants named ``tenant{i}`` on ``backend`` — the layout the
    workload generator and benchmarks address."""
    from repro.service import PGServer, Service

    dev_mesh = None
    if mesh:
        from repro.launch.mesh import make_entity_mesh

        dev_mesh = make_entity_mesh()
    with Service() as svc:
        if backends:
            named = {b: build_tenant_graph(b, m, mesh=dev_mesh, seed=seed)
                     for b in backends}
        else:
            named = {f"tenant{i}": build_tenant_graph(backend, m, mesh=dev_mesh,
                                                      seed=seed + i)
                     for i in range(graphs)}
        pool = pattern_pool()
        for name, pg in named.items():
            svc.add_graph(name, pg)
            if warm:
                warm_serving_path(pg, pool)
        server = PGServer(svc, host=host, port=port).start()
        print(f"PGSERVE LISTENING {server.port}", flush=True)
        server.wait_shutdown()
        server.close()
    print("PGSERVE SERVER EXIT", flush=True)


def spawn_server(extra_args: Sequence[str], *, timeout: float = 180.0):
    """Launch ``pgserve --serve --port 0 <extra_args>`` as a SEPARATE OS
    process and wait for its listening handshake; returns ``(proc, port)``.
    The child inherits the environment (``PYTHONPATH``, ``XLA_FLAGS`` — CI's
    8 virtual devices apply server-side too)."""
    cmd = [sys.executable, "-m", "repro.launch.pgserve", "--serve",
           "--port", "0", *extra_args]
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True, env=env)
    # the handshake wait must not block in readline() itself — a wedged
    # child that stays silent would hang the caller past any deadline — so
    # a pump thread reads lines and the deadline is enforced on the queue
    # (the pump also keeps draining stdout afterwards, so a chatty server
    # can never fill the pipe and stall)
    import queue as _queue

    lines: "_queue.Queue" = _queue.Queue()

    def _pump() -> None:
        for line in proc.stdout:
            lines.put(line)
        lines.put(None)  # EOF

    threading.Thread(target=_pump, name="pgserve-spawn-pump",
                     daemon=True).start()
    deadline = time.monotonic() + timeout
    port = None
    while True:
        try:
            line = lines.get(timeout=max(0.0, deadline - time.monotonic()))
        except _queue.Empty:
            break  # deadline passed with the child alive but silent
        if line is None:
            break  # child exited without the handshake
        if line.startswith("PGSERVE LISTENING "):
            port = int(line.split()[-1])
            break
    if port is None:
        proc.kill()
        raise RuntimeError("server process never reached LISTENING")
    return proc, port


def run_workload_net(port: int, workload: Sequence[Tuple[str, str]],
                     concurrency: int, *, repeats: int = 1,
                     host: str = "127.0.0.1") -> Dict[str, float]:
    """``run_workload`` over TCP: each closed-loop client is its own
    ``PGClient`` CONNECTION (its own session), so the server's batching
    window is fed by genuinely independent sockets."""
    from repro.service import PGClient

    def make_session():
        c = PGClient(host, port=port)
        return c.query, c.close

    return _run_closed_loop(make_session, workload, concurrency,
                            repeats=repeats)


def _assert_wire_result_matches(got, ref, context) -> None:
    assert (np.asarray(got.vertex_mask) == np.asarray(ref.vertex_mask)).all(), context
    assert (np.asarray(got.edge_mask) == np.asarray(ref.edge_mask)).all(), context
    rb = ref.bindings()
    gb = got.bindings()
    assert sorted(gb) == sorted(rb), context
    for k in rb:
        assert (np.asarray(gb[k]) == np.asarray(rb[k])).all(), (context, k)


def _assert_blocks_equal(got, ref, context) -> None:
    """Sampled block lists match bitwise — field by field, layer by layer
    (works across ``SampledBlock`` and ``WireSampledBlock``)."""
    assert len(got) == len(ref), (context, len(got), len(ref))
    for li, (bg, br) in enumerate(zip(got, ref)):
        for f in ("src_nodes", "dst_nodes", "edge_src", "edge_dst",
                  "edge_mask"):
            a, b = np.asarray(getattr(bg, f)), np.asarray(getattr(br, f))
            assert a.shape == b.shape and (a == b).all(), (context, li, f)


def _packed_parity_block(m: int, seed: int) -> None:
    """Packed ≡ byte mask-plane gate (docs/ARCHITECTURE.md §14): the same
    tenant graph built with the bit-packed plane and with the
    ``REPRO_PG_BYTE_MASKS`` byte fallback answers match / khop /
    components / overlay views bitwise-identically — per backend, and on
    the mesh when >1 device is visible (word-axis shards + the packed OR
    all-reduce frontier)."""
    import jax

    from repro.core import bitplane

    pool = pattern_pool()

    def surfaces(pg):
        out = []
        for pattern in pool[:3]:
            res = pg.match(pattern)
            out += [res.vertex_mask, res.edge_mask]
        nodes = np.asarray(pg.graph.node_map)
        out.append(pg.khop(nodes[:4], 2, pattern="(a)-[:follows]->(b)"))
        out.append(pg.components("(a)-[:follows|likes]->(b)"))
        # overlay views: snapshot pins pre-write answers; live sees deltas
        snap = pg.snapshot()
        live = pg.fork()
        live.insert_edges(nodes[:8], nodes[-8:])
        live.add_node_labels(nodes[:8], ["l1"] * 8)
        live.delete_vertices(nodes[9:11])
        out.append(snap.match(pool[0]).vertex_mask)
        out.append(live.match(pool[0]).vertex_mask)
        out.append(live.match(pool[0]).edge_mask)
        return [np.asarray(x) for x in out]

    meshes = [None]
    if len(jax.devices()) > 1:
        from repro.launch.mesh import make_entity_mesh

        meshes.append(make_entity_mesh())
    for mesh in meshes:
        for backend in ("arr", "list", "listd") if mesh is None else ("arr",):
            got = {}
            for packed in (True, False):
                with bitplane.byte_masks(not packed):
                    got[packed] = surfaces(
                        build_tenant_graph(backend, m, mesh=mesh, seed=seed))
            for i, (a, b) in enumerate(zip(got[True], got[False])):
                assert np.array_equal(a, b), (backend, mesh is not None, i)
        where = "mesh" if mesh is not None else "single-device"
        print(f"pgserve smoke: packed ≡ byte mask plane ({where}) OK",
              flush=True)


def net_smoke(m: int = 600, seed: int = 0, tmp_dir: Optional[str] = None) -> None:
    """CI gate for the network path: one server SUBPROCESS serving all
    three backends; a client in THIS process verifies every pool pattern
    bitwise against an in-process ``PropGraph.match`` reference (the
    tenant build is seeded, so both processes construct identical graphs),
    then exercises pipelining, the semiring analytics verbs (weighted
    shortest paths / PageRank / communities), a variable-length traversal
    query (plus the plan-time string-predicate rejection), wire mutation +
    invalidation,
    the save→``load_graph`` path (cross-backend), error isolation, and
    graceful drain/shutdown.  Prints ``PGSERVE NET SMOKE OK``."""
    import tempfile

    from repro.core.io import save_propgraph
    from repro.service import PGClient

    backends = ("arr", "list", "listd")
    pool = pattern_pool()
    refs = {b: build_tenant_graph(b, m, seed=seed) for b in backends}
    proc, port = spawn_server(["--backends", ",".join(backends),
                               "--m", str(m), "--seed", str(seed)])
    try:
        with PGClient(port=port) as c:
            ping = c.ping()
            assert ping, "server did not answer ping"
            assert sorted(c.graphs()) == sorted(backends)
            # blocking queries: every backend, every pattern, bitwise
            for b in backends:
                for pattern in pool:
                    _assert_wire_result_matches(
                        c.query(b, pattern), refs[b].match(pattern), (b, pattern))
                print(f"pgserve net smoke: backend={b} ≡ in-process match OK",
                      flush=True)
            # pipelined burst: one pressure wave, still exact (dups included)
            burst = pool + pool[:4]
            got = c.query_batch("arr", burst)
            for pattern, res in zip(burst, got):
                _assert_wire_result_matches(res, refs["arr"].match(pattern),
                                            ("pipelined", pattern))
            # semiring analytics over the wire (§12): weighted shortest
            # paths and communities bitwise vs the in-process reference,
            # PageRank within float tolerance
            for b in backends:
                seeds = np.asarray(refs[b].graph.node_map)[:4]
                spat = "(a)-[:follows]->(b)"
                assert np.array_equal(
                    c.shortest_paths(b, seeds, weight="w", pattern=spat),
                    np.asarray(refs[b].shortest_paths(
                        seeds, weight="w", pattern=spat))), ("sp", b)
                assert np.allclose(
                    c.pagerank(b, weight="w"),
                    np.asarray(refs[b].pagerank(weight="w")),
                    atol=1e-6), ("pagerank", b)
                assert np.array_equal(
                    c.communities(b),
                    np.asarray(refs[b].communities())), ("communities", b)
            print("pgserve net smoke: weighted analytics ≡ in-process OK",
                  flush=True)
            # fused sampling over the wire (§15): deterministic-mode blocks
            # are bitwise the in-process ``PropGraph.sample`` ones on every
            # backend — explicit seeds, pattern seeds with an edge filter,
            # and a pipelined burst the server coalesces into one launch
            # per (graph, fanouts, bucket) group
            for b in backends:
                nb = np.asarray(refs[b].graph.node_map)
                _assert_blocks_equal(
                    c.sample(b, nb[:48], [4, 3], seed=7),
                    refs[b].sample(nb[:48], [4, 3], seed=7),
                    ("net sample", b))
            nb = np.asarray(refs["arr"].graph.node_map)
            _assert_blocks_equal(
                c.sample("arr", "(a:l0)", [4],
                         pattern="(a)-[:follows]->(b)", seed=3),
                refs["arr"].sample("(a:l0)", [4],
                                   pattern="(a)-[:follows]->(b)", seed=3),
                "net pattern sample")
            shs = [c.submit_sample("arr", nb[8 * i:8 * i + 24], [3], seed=i)
                   for i in range(6)]
            for i, h in enumerate(shs):
                _assert_blocks_equal(
                    h.result(),
                    refs["arr"].sample(nb[8 * i:8 * i + 24], [3], seed=i),
                    ("net pipelined sample", i))
            print("pgserve net smoke: fused sampling ≡ in-process OK",
                  flush=True)
            # explain crosses the wire as text
            assert "plan" in c.explain("arr", pool[0]).lower()
            # variable-length traversal over the wire: frontier-engine
            # propagation server-side, masks bitwise vs in-process match
            vpat = "(a:l1)-[:follows*1..4]->(b:l2)"
            for b in backends:
                _assert_wire_result_matches(
                    c.query(b, vpat), refs[b].match(vpat), ("varlen", b))
            assert "traverse" in c.explain("arr", vpat)
            print("pgserve net smoke: variable-length query ≡ in-process OK",
                  flush=True)
            # plan-time rejection reaches the client BEFORE any execution:
            # a string predicate fails with TypeError naming the column
            try:
                c.query("arr", '(a {age == "old"})-[:follows]->(b)')
            except TypeError as e:
                assert "age" in str(e)
            else:
                raise AssertionError("string predicate should raise TypeError")
            # mutation over the wire: version bump + cache invalidation,
            # mirrored locally on the reference graph
            nodes = np.asarray(refs["arr"].graph.node_map)
            v = c.add_node_labels("arr", nodes[:7], ["l1"] * 7)
            assert v == refs["arr"].add_node_labels(nodes[:7], ["l1"] * 7).version
            _assert_wire_result_matches(c.query("arr", pool[0]),
                                        refs["arr"].match(pool[0]),
                                        ("post-mutation", pool[0]))
            # overlay over the wire: snapshot pins the pre-write state, the
            # fork branches privately, compact folds the overlay back in —
            # every step bitwise vs the mirrored in-process graph
            snap = c.snapshot("arr")
            snap_ref = {p: refs["arr"].match(p) for p in pool[:2]}
            v = c.insert_edges("arr", nodes[:12], nodes[-12:])
            refs["arr"].insert_edges(nodes[:12], nodes[-12:])
            assert v == refs["arr"].version
            c.add_node_labels("arr", nodes[:5], ["l2"] * 5)
            refs["arr"].add_node_labels(nodes[:5], ["l2"] * 5)
            for p in pool[:2]:
                _assert_wire_result_matches(c.query(snap, p), snap_ref[p],
                                            ("snapshot", p))
                _assert_wire_result_matches(c.query("arr", p),
                                            refs["arr"].match(p),
                                            ("overlay-live", p))
            fork = c.fork_view("arr")
            c.delete_vertices(fork, nodes[:1])
            fref = refs["arr"].fork()
            fref.delete_vertices(nodes[:1])
            _assert_wire_result_matches(c.query(fork, pool[0]),
                                        fref.match(pool[0]), "fork")
            _assert_wire_result_matches(c.query("arr", pool[0]),
                                        refs["arr"].match(pool[0]),
                                        "fork-parent")
            ov = c.compact("arr")
            assert ov["delta_edges"] > 0, ov
            refs["arr"].compact()
            _assert_wire_result_matches(c.query("arr", pool[0]),
                                        refs["arr"].match(pool[0]),
                                        "post-compact")
            c.drop_view(fork)
            c.drop_view(snap)
            remaining = c.graphs()
            assert fork not in remaining and snap not in remaining
            print("pgserve net smoke: overlay snapshot/fork/compact ≡ "
                  "in-process OK", flush=True)
            # save here → load_graph there (cross-backend reopen via wire)
            with tempfile.TemporaryDirectory(dir=tmp_dir) as td:
                path = save_propgraph(os.path.join(td, "pg"), refs["arr"])
                info = c.load_graph("disk", path, backend="listd")
                assert info["backend"] == "listd"
                _assert_wire_result_matches(c.query("disk", pool[1]),
                                            refs["arr"].match(pool[1]),
                                            "load_graph")
                # with >1 device server-side (CI forces 8), reopen the same
                # save onto the server's entity mesh: the §7 sharded path,
                # driven cross-process, must stay bitwise too
                devices = c.server_info().get("devices", 1)
                if devices > 1:
                    c.load_graph("sharded", path, backend="arr", mesh=True)
                    for pattern in pool[:4]:
                        _assert_wire_result_matches(
                            c.query("sharded", pattern),
                            refs["arr"].match(pattern), ("sharded", pattern))
                    # weighted analytics against the mesh-placed reopen:
                    # tropical pmin exact, counting psum within atol —
                    # driven cross-process under the CI's 8 virtual devices
                    seeds = np.asarray(refs["arr"].graph.node_map)[:4]
                    assert np.array_equal(
                        c.shortest_paths("sharded", seeds, weight="w"),
                        np.asarray(refs["arr"].shortest_paths(
                            seeds, weight="w"))), "sharded sp"
                    assert np.allclose(
                        c.pagerank("sharded"),
                        np.asarray(refs["arr"].pagerank()),
                        atol=1e-5), "sharded pagerank"
                    # fused sampling against the mesh-placed reopen, driven
                    # cross-process: sampling stays owner-device local and
                    # the blocks come back bitwise the unsharded ones
                    _assert_blocks_equal(
                        c.sample("sharded", seeds.astype(np.int64), [4],
                                 seed=5),
                        refs["arr"].sample(seeds, [4], seed=5),
                        "sharded sample")
                    print(f"pgserve net smoke: sharded P={devices} ≡ "
                          "single-device OK", flush=True)
                else:
                    print("pgserve net smoke: sharded check skipped (1 device)",
                          flush=True)
            # a bad request fails alone, with the real exception type
            try:
                c.query("arr", "(a {nosuchprop > 1})-[:follows]->(b)")
            except KeyError as e:
                assert "nosuchprop" in str(e)
            else:
                raise AssertionError("bad property should raise KeyError")
            assert c.ping()  # session survived the failed request
            # metrics verb (§13): the Prometheus exposition parses, counters
            # are monotonic across a pipelined burst, the totals agree with
            # the stats verb, and the span tree round-trips the client's
            # trace id
            from repro.obs import parse_prometheus

            m1 = parse_prometheus(c.metrics())
            hs = [c.submit("arr", p) for p in pool[:8]]
            for h in hs:
                h.result()
            assert hs[0].trace is not None, "trace header missing"
            assert hs[0].trace["trace_id"] == hs[0].trace_id
            m2 = parse_prometheus(c.metrics())
            assert (m2["pg_service_submitted_total"]
                    == m1["pg_service_submitted_total"] + len(hs))
            totals = [k for k in m1 if k.endswith("_total")]
            assert totals and all(m2.get(k, 0.0) >= m1[k] for k in totals), \
                "counters went backwards"
            stats = c.stats()
            assert m2["pg_service_submitted_total"] == stats["submitted"]
            assert m2["pg_service_completed_total"] == stats["completed"]
            print("pgserve net smoke: metrics verb + trace round-trip OK",
                  flush=True)
            assert stats.get("completed", 0) > 0
            c.drain()
            c.shutdown()
        assert proc.wait(timeout=60) == 0, "server exit code"
    finally:
        if proc.poll() is None:
            proc.kill()
    _packed_parity_block(m, seed)
    print("PGSERVE NET SMOKE OK")


def _verify_bitwise(service, graphs: Dict[str, object],
                    pool: Sequence[str]) -> None:
    """Service answers ≡ direct ``match()`` for every (graph, pattern)."""
    for name, pg in graphs.items():
        for pattern in pool:
            ref = pg.match(pattern)
            got = service.query(name, pattern)
            assert (np.asarray(got.vertex_mask) == np.asarray(ref.vertex_mask)).all(), \
                (name, pattern)
            assert (np.asarray(got.edge_mask) == np.asarray(ref.edge_mask)).all(), \
                (name, pattern)


def smoke(m: int = 600, requests: int = 24, concurrency: int = 4,
          seed: int = 0) -> None:
    """CI gate: service ≡ direct match on all three backends (and on a
    device mesh when >1 device is visible), invalidation works, and the
    arr path actually coalesced.  Prints ``PGSERVE SMOKE OK``."""
    import jax

    from repro.service import Service

    pool = pattern_pool()
    for backend in ("arr", "list", "listd"):
        pg = build_tenant_graph(backend, m, seed=seed)
        with Service() as svc:
            svc.add_graph("g", pg)
            wl = synthetic_workload(["g"], pool, requests, seed=seed)
            run_workload(svc, wl, concurrency)
            _verify_bitwise(svc, {"g": pg}, pool)
            # semiring analytics through the service (§12): weighted
            # traversal (tropical), PageRank (counting) and communities
            # (mode) match the direct PropGraph calls; the repeat probe is
            # a result-cache hit returning the identical array
            seeds = np.asarray(pg.graph.node_map)[:4]
            spat = "(a)-[:follows]->(b)"
            sp = svc.shortest_paths("g", seeds, weight="w", pattern=spat)
            assert np.array_equal(sp, np.asarray(pg.shortest_paths(
                seeds, weight="w", pattern=spat))), backend
            assert np.isfinite(sp).any(), backend
            pr = svc.pagerank("g", weight="w")
            assert np.array_equal(pr, np.asarray(pg.pagerank(weight="w"))), \
                backend
            assert abs(float(np.sum(pr)) - 1.0) < 1e-3, backend
            cm = svc.communities("g")
            assert np.array_equal(cm, np.asarray(pg.communities())), backend
            hits0 = svc.stats().get("result_hits", 0)
            assert np.array_equal(sp, svc.shortest_paths(
                "g", seeds, weight="w", pattern=spat)), backend
            assert svc.stats().get("result_hits", 0) > hits0, backend
            # variable-length traversal through the service (per-request
            # fallback in the coalescer, result cache still serves it)
            vpat = "(a:l1)-[:follows*1..3]->(b:l2)"
            got = svc.query("g", vpat)
            ref = pg.match(vpat)
            assert (np.asarray(got.edge_mask) == np.asarray(ref.edge_mask)).all(), backend
            assert svc.stats().get("traversal_fallback_requests", 0) > 0, backend
            # mutation → version bump → cached results die
            before = svc.query("g", pool[0])
            nodes = np.asarray(pg.graph.node_map)
            pg.add_node_labels(nodes[:5], ["l1"] * 5)
            after = svc.query("g", pool[0])
            ref = pg.match(pool[0])
            assert (np.asarray(after.vertex_mask) == np.asarray(ref.vertex_mask)).all()
            stats = svc.stats()
            assert stats.get("invalidated_results", 0) > 0, backend
            if backend == "arr":
                assert stats.get("coalesced_launches", 0) > 0, stats
            else:
                assert stats.get("fallback_requests", 0) > 0, stats
        print(f"pgserve smoke: backend={backend} OK "
              f"(coalesced_launches={stats.get('coalesced_launches', 0)}, "
              f"result_hits={stats.get('result_hits', 0)})")

    # overlay: snapshot isolation, fork what-if and compaction through the
    # service verbs (docs/ARCHITECTURE.md §11)
    pg = build_tenant_graph("arr", m, seed=seed)
    ref = build_tenant_graph("arr", m, seed=seed)  # stays at the pinned state
    with Service() as svc:
        svc.add_graph("g", pg)
        snap = svc.snapshot_graph("g")
        nodes = np.asarray(pg.graph.node_map)
        pg.insert_edges(nodes[:16], nodes[-16:])  # delta, behind the snapshot
        pg.add_node_labels(nodes[:8], ["l1"] * 8)
        assert pg.delta_stats()["delta_edges"] > 0
        for pattern in pool[:3]:
            got = svc.query(snap, pattern)  # pinned: pre-write answers
            refr = ref.match(pattern)
            assert (np.asarray(got.vertex_mask) == np.asarray(refr.vertex_mask)).all(), pattern
            assert (np.asarray(got.edge_mask) == np.asarray(refr.edge_mask)).all(), pattern
            live = svc.query("g", pattern)  # live: overlay applied
            liver = pg.match(pattern)
            assert (np.asarray(live.edge_mask) == np.asarray(liver.edge_mask)).all(), pattern
        # fork: a private delete; the parent keeps serving unchanged
        fork = svc.fork_graph("g")
        fpg = svc.registry.get(fork)
        fpg.delete_vertices(nodes[:1])
        fgot = svc.query(fork, pool[0])
        assert (np.asarray(fgot.vertex_mask)
                == np.asarray(fpg.match(pool[0]).vertex_mask)).all()
        pgot = svc.query("g", pool[0])
        assert (np.asarray(pgot.vertex_mask)
                == np.asarray(pg.match(pool[0]).vertex_mask)).all()
        # compact folds the overlay in; live answers and the snapshot's
        # pinned answers both survive it
        svc.compact_graph("g")
        assert not pg.has_overlay()
        post = svc.query("g", pool[1])
        assert (np.asarray(post.edge_mask)
                == np.asarray(pg.match(pool[1]).edge_mask)).all()
        sgot = svc.query(snap, pool[0])
        assert (np.asarray(sgot.vertex_mask)
                == np.asarray(ref.match(pool[0]).vertex_mask)).all()
        svc.drop_graph(fork)
        svc.drop_graph(snap)
    print("pgserve smoke: overlay snapshot/fork/compact OK")

    # fused neighborhood sampling through the service (§15): deterministic
    # requests are bitwise the direct ``PropGraph.sample`` blocks —
    # explicit and pattern seeds, filtered and unfiltered, multi-layer;
    # a coalesced burst launches once per (graph, fanouts, bucket) group
    # with every row still bitwise its solo run; deterministic repeats hit
    # the result cache
    pg = build_tenant_graph("arr", m, seed=seed)
    with Service() as svc:
        svc.add_graph("g", pg)
        nodes = np.asarray(pg.graph.node_map)
        for fanouts, filt in (([4, 3], None),
                              ([5], "(a)-[:follows]->(b)")):
            _assert_blocks_equal(
                svc.sample("g", nodes[:48], fanouts, pattern=filt, seed=7),
                pg.sample(nodes[:48], fanouts, pattern=filt, seed=7),
                ("sample", fanouts, filt))
        _assert_blocks_equal(
            svc.sample("g", "(a:l0)", [4], pattern="(a)-[:likes]->(b)",
                       seed=3),
            pg.sample("(a:l0)", [4], pattern="(a)-[:likes]->(b)", seed=3),
            "pattern-seed sample")
        specs = [(nodes[8 * i:8 * i + 32], i) for i in range(8)]
        launches0 = svc.stats().get("sample_coalesced_launches", 0)
        batch = svc.sample_batch("g", specs, [3])
        assert svc.stats().get("sample_coalesced_launches", 0) == launches0 + 1
        for (s, sv), bl in zip(specs, batch):
            _assert_blocks_equal(bl, pg.sample(s, [3], seed=sv),
                                 ("coalesced sample", sv))
        hits0 = svc.stats().get("result_hits", 0)
        svc.sample("g", nodes[:48], [4, 3], seed=7)
        assert svc.stats().get("result_hits", 0) > hits0, "sample cache miss"
    print("pgserve smoke: fused sampling ≡ in-process OK")

    # observability (§13): EXPLAIN ANALYZE splits compile from steady-state,
    # the metrics exposition parses and agrees with stats(), counters are
    # monotonic across a second burst, the trace ring holds full span trees,
    # and the disabled path still answers queries bitwise-identically
    from repro.obs import parse_prometheus, set_enabled

    pg = build_tenant_graph("arr", m, seed=seed)
    with Service() as svc:
        svc.add_graph("g", pg)
        rep = pg.explain_analyze(pool[0])
        rep2 = pg.explain_analyze(pool[0])  # warm: compile already paid
        assert rep.total_first_ms >= rep.steady_ms >= 0
        assert rep2.compile_ms <= rep.compile_ms
        wl = synthetic_workload(["g"], pool, requests, seed=seed + 1)
        run_workload(svc, wl, concurrency)
        m1 = parse_prometheus(svc.metrics_text())
        st = svc.stats()
        assert m1["pg_service_submitted_total"] == st["submitted"]
        assert m1["pg_service_completed_total"] == st["completed"]
        run_workload(svc, wl, concurrency)
        m2 = parse_prometheus(svc.metrics_text())
        assert (m2["pg_service_submitted_total"]
                == m1["pg_service_submitted_total"] + len(wl))
        totals = [k for k in m1 if k.endswith("_total")]
        assert totals and all(m2.get(k, 0.0) >= m1[k] for k in totals), \
            "counters went backwards"
        tl = svc.trace_log()
        assert tl, "trace ring empty"
        names = {s["name"] for t in tl for s in t.get("spans", [])}
        assert "execute" in names or "cache" in names, names
        prev = set_enabled(False)
        try:
            before = svc.stats().get("submitted", 0)
            got = svc.query("g", pool[1])
            ref = pg.match(pool[1])
            assert (np.asarray(got.edge_mask)
                    == np.asarray(ref.edge_mask)).all()
            assert svc.stats().get("submitted", 0) == before, \
                "disabled metrics still counted"
        finally:
            set_enabled(prev)
    print("pgserve smoke: observability (metrics/traces/explain_analyze) OK")

    if len(jax.devices()) > 1:
        from repro.launch.mesh import make_entity_mesh

        mesh = make_entity_mesh()
        pg1 = build_tenant_graph("arr", m, seed=seed)
        pg2 = build_tenant_graph("arr", m, mesh=mesh, seed=seed)
        with Service() as svc:
            svc.add_graph("sharded", pg2)
            for pattern in pool[:4]:
                ref = pg1.match(pattern)
                got = svc.query_batch("sharded", [pattern])[0]
                assert (np.asarray(got.edge_mask) == np.asarray(ref.edge_mask)).all(), \
                    pattern
            # weighted analytics on the mesh: the tropical relax pmin
            # all-reduce is exact (bitwise vs the unsharded graph), the
            # PageRank psum reassociates (atol)
            seeds = np.asarray(pg1.graph.node_map)[:4]
            assert np.array_equal(
                svc.shortest_paths("sharded", seeds, weight="w"),
                np.asarray(pg1.shortest_paths(seeds, weight="w")))
            assert np.allclose(svc.pagerank("sharded", weight="w"),
                               np.asarray(pg1.pagerank(weight="w")), atol=1e-5)
            # fused sampling on the mesh: the seed bitmap and packed edge
            # filter live word-sharded, the draw is replicated — blocks
            # are bitwise the unsharded graph's (§15 locality rule)
            _assert_blocks_equal(
                svc.sample("sharded", np.asarray(pg1.graph.node_map)[:32],
                           [4], pattern="(a)-[:follows]->(b)", seed=5),
                pg1.sample(np.asarray(pg1.graph.node_map)[:32], [4],
                           pattern="(a)-[:follows]->(b)", seed=5),
                "mesh sample")
        print(f"pgserve smoke: mesh P={len(mesh.devices)} ≡ single-device OK")
    else:
        print("pgserve smoke: mesh check skipped (1 device)")
    _packed_parity_block(m, seed)
    print("PGSERVE SMOKE OK")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fast correctness pass for CI; exits non-zero on failure")
    ap.add_argument("--serve", action="store_true",
                    help="run as a foreground pgd server process")
    ap.add_argument("--net", action="store_true",
                    help="cross-process mode: spawn a server, drive it over TCP")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="--serve bind port (0 = OS-assigned, printed on stdout)")
    ap.add_argument("--backends", default=None,
                    help="--serve: comma list; one graph per backend, named after it")
    ap.add_argument("--warm", action="store_true",
                    help="--serve: pre-compile the serving path before LISTENING")
    ap.add_argument("--graphs", type=int, default=2, help="tenant graph count")
    ap.add_argument("--backend", default="arr", choices=("arr", "list", "listd"))
    ap.add_argument("--m", type=int, default=20_000, help="edges per tenant graph")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--mesh", action="store_true",
                    help="place tenant graphs on an entity mesh over all devices")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the Prometheus exposition after the workload "
                         "(fetched over the wire in --net mode)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.serve:
        serve(port=args.port, host=args.host, backend=args.backend,
              backends=args.backends.split(",") if args.backends else None,
              graphs=args.graphs, m=args.m, seed=args.seed, mesh=args.mesh,
              warm=args.warm)
        return
    if args.net and args.smoke:
        net_smoke(seed=args.seed)
        return
    if args.net:
        proc, port = spawn_server(["--host", args.host,
                                   "--graphs", str(args.graphs),
                                   "--backend", args.backend,
                                   "--m", str(args.m),
                                   "--seed", str(args.seed), "--warm"])
        try:
            names = [f"tenant{i}" for i in range(args.graphs)]
            wl = synthetic_workload(names, pattern_pool(), args.requests,
                                    seed=args.seed)
            met = run_workload_net(port, wl, args.concurrency, host=args.host)
            print(f"net service (c={args.concurrency}): {met['qps']:.1f} qps, "
                  f"p50={met['p50_ms']:.2f}ms p95={met['p95_ms']:.2f}ms")
            from repro.service import PGClient

            with PGClient(args.host, port=port) as c:
                print(f"stats: {c.stats()}")
                if args.metrics:
                    print(c.metrics(), end="")
                c.shutdown()
            proc.wait(timeout=60)
        finally:
            if proc.poll() is None:
                proc.kill()
        return
    if args.smoke:
        smoke(seed=args.seed)
        return

    from repro.service import Service

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_entity_mesh

        mesh = make_entity_mesh()
    graphs = {
        f"tenant{i}": build_tenant_graph(args.backend, args.m, mesh=mesh,
                                         seed=args.seed + i)
        for i in range(args.graphs)
    }
    pool = pattern_pool()
    wl = synthetic_workload(sorted(graphs), pool, args.requests, seed=args.seed)

    for pg in graphs.values():  # steady-state numbers, not compile time
        warm_serving_path(pg, pool)
    seq = run_sequential(graphs, wl)
    print(f"sequential baseline: {seq['qps']:.1f} qps ({seq['wall_s']:.2f}s)")

    with Service() as svc:
        for name, pg in graphs.items():
            svc.add_graph(name, pg)
        metrics = run_workload(svc, wl, args.concurrency)
        stats = svc.stats()
        exposition = svc.metrics_text() if args.metrics else None
    print(f"service (c={args.concurrency}): {metrics['qps']:.1f} qps, "
          f"p50={metrics['p50_ms']:.2f}ms p95={metrics['p95_ms']:.2f}ms, "
          f"speedup ×{metrics['qps'] / seq['qps']:.2f}")
    print(f"stats: {stats}")
    if exposition is not None:
        print(exposition, end="")


if __name__ == "__main__":
    main()
