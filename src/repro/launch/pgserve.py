"""pgserve — CLI driver for the graph analytics service (src/repro/service/).

Builds named tenant graphs, generates a synthetic multi-tenant pattern
workload (zipf-skewed over a pattern pool — hot patterns repeat, like real
dashboards), and drives a ``Service`` with closed-loop concurrent clients,
reporting throughput/latency and the service's coalescing/cache counters.

    # throughput report: 2 tenant graphs, 64 requests, 8 concurrent clients
    PYTHONPATH=src python -m repro.launch.pgserve --graphs 2 --requests 64 \
        --concurrency 8

    # CI smoke: correctness across all backends (+ mesh when >1 device)
    PYTHONPATH=src python -m repro.launch.pgserve --smoke

The workload/runner helpers here are also the benchmark's building blocks
(``benchmarks/bench_serve.py`` imports them), so the CLI and the benchmark
measure the same thing.
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "build_tenant_graph",
    "pattern_pool",
    "synthetic_workload",
    "run_workload",
    "run_sequential",
    "smoke",
    "main",
]

N_LABELS = 12
RELS = ("follows", "likes")


def build_tenant_graph(backend: str, m: int, *, mesh=None, seed: int = 0):
    """One synthetic tenant: Tab.-I-regime random graph with labels
    ``l0..l{N_LABELS-1}``, relationships ``follows``/``likes`` and an
    ``age`` property — the attribute shape every pool pattern queries."""
    from repro.core import PropGraph
    from repro.graph import random_uniform_graph

    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend, mesh=mesh).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes, rng.choice([f"l{i}" for i in range(N_LABELS)],
                                         size=len(nodes)))
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.add_edge_relationships(nodes[es], nodes[ed],
                              rng.choice(RELS, size=len(es)))
    pg.add_node_properties("age", nodes,
                           rng.integers(0, 90, len(nodes)).astype(np.int32))
    return pg


def pattern_pool() -> List[str]:
    """The query mix: 1-hop label/relationship shapes, predicate filters,
    reverse hops and a 2-hop chain — every planner path gets traffic."""
    return [
        "(a:l1|l2)-[:follows]->(b:l3)",
        "(a:l0)-[:likes]->(b:l4|l5)",
        "(a:l6 {age > 30})-[:follows]->(b)",
        "(a)<-[:likes]-(b:l7|l8)",
        "(a:l9)-[:follows]->(b:l10)",
        "(a:l2|l3 {age <= 60})-[:likes]->(b:l0)",
        "(a:l11)-[:likes]->(b:l1)",
        "(a:l4)-[:follows]->(b)-[:likes]->(c:l5)",
        "(a:l5|l6)-[:follows]->(b:l7)",
        "(a:l8 {age >= 18})-[:likes]->(b:l9|l10)",
        "(a:l3)<-[:follows]-(b:l2)",
        "(a:l0|l1|l2)-[:likes]->(b:l3|l4|l5)",
    ]


def synthetic_workload(
    graph_names: Sequence[str],
    pool: Sequence[str],
    n_requests: int,
    *,
    seed: int = 0,
    skew: float = 1.1,
) -> List[Tuple[str, str]]:
    """(graph, pattern) stream: tenants drawn uniformly, patterns drawn
    zipf-skewed (weight ∝ 1/rank^skew) — a hot head and a long tail, the
    distribution request coalescing and result caching are built for."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
    w = ranks ** -skew
    w /= w.sum()
    return [
        (graph_names[int(rng.integers(len(graph_names)))],
         pool[int(rng.choice(len(pool), p=w))])
        for _ in range(n_requests)
    ]


def run_workload(service, workload: Sequence[Tuple[str, str]],
                 concurrency: int, *, repeats: int = 1) -> Dict[str, float]:
    """Closed-loop clients: the workload splits round-robin over
    ``concurrency`` threads; each client submits its next request only
    after the previous one resolved.  Returns wall/qps/latency metrics.

    ``repeats`` > 1 replays the workload and keeps the best-throughput
    run (latencies from that run) — multithreaded closed loops are highly
    exposed to cgroup CPU-quota throttling and noisy neighbors, and the
    best run is the least-interfered estimate of the service's own cost.
    Replays hit warm caches; measure cold behavior with ``repeats=1`` on
    a fresh ``Service``."""
    if repeats > 1:
        runs = [run_workload(service, workload, concurrency) for _ in range(repeats)]
        return max(runs, key=lambda r: r["qps"])
    lat_lock = threading.Lock()
    latencies: List[float] = []
    errors: List[BaseException] = []

    def client(items: List[Tuple[str, str]]) -> None:
        for graph, pattern in items:
            t0 = time.monotonic()
            try:
                fut = service.submit(graph, pattern)
                fut.result(timeout=120)
            except BaseException as e:  # noqa: BLE001 — reported, not raised
                with lat_lock:
                    errors.append(e)
                return
            with lat_lock:
                latencies.append(time.monotonic() - t0)

    shards = [list(workload[i::concurrency]) for i in range(concurrency)]
    threads = [threading.Thread(target=client, args=(s,)) for s in shards if s]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    if errors:
        raise errors[0]
    lat = np.sort(np.asarray(latencies))
    return {
        "wall_s": wall,
        "qps": len(workload) / wall,
        "p50_ms": float(lat[len(lat) // 2] * 1e3),
        "p95_ms": float(lat[min(int(len(lat) * 0.95), len(lat) - 1)] * 1e3),
    }


def warm_serving_path(pg, pool: Sequence[str], *, max_masks: int = 64) -> None:
    """Compile everything steady-state serving will hit: each pattern's
    propagation program (direct match) and the batched store queries at
    every Q bucket ≤ ``max_masks`` — batch composition varies with load,
    and an unvisited bucket would otherwise pay its compile inside a
    measured (or served) window."""
    import jax

    from repro.kernels.bitmap_query.ops import Q_BUCKETS, bucketed_q

    for p in pool:
        jax.block_until_ready(pg.match(p))
    for b in Q_BUCKETS:
        jax.block_until_ready(pg._vstore.query_any_batched([()] * b))
        jax.block_until_ready(pg._estore.query_any_batched([()] * b))
        if b >= bucketed_q(max_masks):
            break


def run_sequential(graphs: Dict[str, object],
                   workload: Sequence[Tuple[str, str]], *,
                   repeats: int = 1) -> Dict[str, float]:
    """The per-request baseline: every request is a cold, single-tenant
    ``PropGraph.match`` call, one after another (no service, no caches, no
    coalescing).  ``repeats`` keeps the best run, like ``run_workload``."""
    import jax

    best = None
    for _ in range(max(repeats, 1)):
        t0 = time.monotonic()
        for graph, pattern in workload:
            jax.block_until_ready(graphs[graph].match(pattern))
        wall = time.monotonic() - t0
        if best is None or wall < best:
            best = wall
    return {"wall_s": best, "qps": len(workload) / best}


def _verify_bitwise(service, graphs: Dict[str, object],
                    pool: Sequence[str]) -> None:
    """Service answers ≡ direct ``match()`` for every (graph, pattern)."""
    for name, pg in graphs.items():
        for pattern in pool:
            ref = pg.match(pattern)
            got = service.query(name, pattern)
            assert (np.asarray(got.vertex_mask) == np.asarray(ref.vertex_mask)).all(), \
                (name, pattern)
            assert (np.asarray(got.edge_mask) == np.asarray(ref.edge_mask)).all(), \
                (name, pattern)


def smoke(m: int = 600, requests: int = 24, concurrency: int = 4,
          seed: int = 0) -> None:
    """CI gate: service ≡ direct match on all three backends (and on a
    device mesh when >1 device is visible), invalidation works, and the
    arr path actually coalesced.  Prints ``PGSERVE SMOKE OK``."""
    import jax

    from repro.service import Service

    pool = pattern_pool()
    for backend in ("arr", "list", "listd"):
        pg = build_tenant_graph(backend, m, seed=seed)
        with Service() as svc:
            svc.add_graph("g", pg)
            wl = synthetic_workload(["g"], pool, requests, seed=seed)
            run_workload(svc, wl, concurrency)
            _verify_bitwise(svc, {"g": pg}, pool)
            # mutation → version bump → cached results die
            before = svc.query("g", pool[0])
            nodes = np.asarray(pg.graph.node_map)
            pg.add_node_labels(nodes[:5], ["l1"] * 5)
            after = svc.query("g", pool[0])
            ref = pg.match(pool[0])
            assert (np.asarray(after.vertex_mask) == np.asarray(ref.vertex_mask)).all()
            stats = svc.stats()
            assert stats.get("invalidated_results", 0) > 0, backend
            if backend == "arr":
                assert stats.get("coalesced_launches", 0) > 0, stats
            else:
                assert stats.get("fallback_requests", 0) > 0, stats
        print(f"pgserve smoke: backend={backend} OK "
              f"(coalesced_launches={stats.get('coalesced_launches', 0)}, "
              f"result_hits={stats.get('result_hits', 0)})")

    if len(jax.devices()) > 1:
        from repro.launch.mesh import make_entity_mesh

        mesh = make_entity_mesh()
        pg1 = build_tenant_graph("arr", m, seed=seed)
        pg2 = build_tenant_graph("arr", m, mesh=mesh, seed=seed)
        with Service() as svc:
            svc.add_graph("sharded", pg2)
            for pattern in pool[:4]:
                ref = pg1.match(pattern)
                got = svc.query_batch("sharded", [pattern])[0]
                assert (np.asarray(got.edge_mask) == np.asarray(ref.edge_mask)).all(), \
                    pattern
        print(f"pgserve smoke: mesh P={len(mesh.devices)} ≡ single-device OK")
    else:
        print("pgserve smoke: mesh check skipped (1 device)")
    print("PGSERVE SMOKE OK")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fast correctness pass for CI; exits non-zero on failure")
    ap.add_argument("--graphs", type=int, default=2, help="tenant graph count")
    ap.add_argument("--backend", default="arr", choices=("arr", "list", "listd"))
    ap.add_argument("--m", type=int, default=20_000, help="edges per tenant graph")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--mesh", action="store_true",
                    help="place tenant graphs on an entity mesh over all devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.smoke:
        smoke(seed=args.seed)
        return

    from repro.service import Service

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_entity_mesh

        mesh = make_entity_mesh()
    graphs = {
        f"tenant{i}": build_tenant_graph(args.backend, args.m, mesh=mesh,
                                         seed=args.seed + i)
        for i in range(args.graphs)
    }
    pool = pattern_pool()
    wl = synthetic_workload(sorted(graphs), pool, args.requests, seed=args.seed)

    for pg in graphs.values():  # steady-state numbers, not compile time
        warm_serving_path(pg, pool)
    seq = run_sequential(graphs, wl)
    print(f"sequential baseline: {seq['qps']:.1f} qps ({seq['wall_s']:.2f}s)")

    with Service() as svc:
        for name, pg in graphs.items():
            svc.add_graph(name, pg)
        metrics = run_workload(svc, wl, args.concurrency)
        stats = svc.stats()
    print(f"service (c={args.concurrency}): {metrics['qps']:.1f} qps, "
          f"p50={metrics['p50_ms']:.2f}ms p95={metrics['p95_ms']:.2f}ms, "
          f"speedup ×{metrics['qps'] / seq['qps']:.2f}")
    print(f"stats: {stats}")


if __name__ == "__main__":
    main()
