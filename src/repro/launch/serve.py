"""Serving launcher — batched autoregressive decode with a KV cache.

CPU container: smoke-config serving demo (real batched decode steps).
TPU fleet: full configs with the production sharding (see steps._lm_cell).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

__all__ = ["serve_demo", "main"]


def serve_demo(arch_id: str, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
               greedy: bool = True):
    from repro.configs.registry import get_arch
    from repro.models import transformer as T

    mod = get_arch(arch_id)
    if mod.FAMILY != "lm":
        raise SystemExit(f"{arch_id} is not an LM; serve supports the LM family")
    cfg = mod.smoke_config()
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab, jnp.int32)

    max_len = prompt_len + gen
    cache = T.init_cache(cfg, batch, max_len)
    dec = jax.jit(T.decode_step, static_argnames="cfg")

    # prefill via decode loop (smoke scale; full prefill kernel covers TPU)
    t0 = time.time()
    toks = jnp.zeros((batch, max_len), jnp.int32).at[:, :prompt_len].set(prompts)
    out = []
    for t in range(max_len - 1):
        logits, cache = dec(params, cache, toks[:, t: t + 1], cfg)
        if t >= prompt_len - 1:
            nxt = (jnp.argmax(logits[:, 0], -1, keepdims=True).astype(jnp.int32)
                   if greedy else
                   jax.random.categorical(
                       jax.random.fold_in(key, t), logits[:, 0])[:, None].astype(jnp.int32))
            out.append(nxt)
            toks = toks.at[:, t + 1: t + 2].set(nxt)
    gen_toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    tput = batch * gen / dt
    print(f"generated {gen_toks.shape} in {dt:.2f}s  ({tput:.1f} tok/s incl. compile)")
    return gen_toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve_demo(args.arch, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
