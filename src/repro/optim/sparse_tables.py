"""Sparse embedding-table updates — the signature recsys-training optimization.

A dense AdamW step on DLRM touches every row of every table (26 × 10⁶ × 64
params + two moments: ~2.5 GB/device/step of pure optimizer traffic — the
measured memory-dominant term of the dlrm train_batch roofline cell).  But a
batch references at most batch×n_sparse×multi_hot rows; everything else is a
no-op (zero gradient) except AdamW's decay/moment bookkeeping.

This module provides the standard production fix: **rowwise-AdaGrad applied
only to touched rows**:

  * forward uses `jnp.take` as usual; the gradient w.r.t. tables is never
    materialized densely — instead the caller passes the batch's indices and
    the upstream gradient of the gathered rows (`pulled_grad`), available from
    `jax.vjp` on the gather output,
  * duplicate indices within the batch are combined with a segment-sum,
  * the optimizer state is one f32 scalar per row (rowwise AdaGrad — the
    DLRM/FBGEMM standard), 192× smaller than AdamW's two full moments,
  * the update is a `scatter`-apply: O(touched rows) instead of O(table).

Napkin (dlrm-rm2 train_batch): touched ≤ 65536×26 = 1.7 M rows of 26 M
(≤6.5%) ⇒ ≥15× less optimizer traffic, and state shrinks 26M×64×2×4 B →
26M×4 B (128×).  Verified numerically against the dense reference in
tests/test_optim_sparse.py.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_rowwise_state", "sparse_table_update", "dense_rowwise_update"]


def init_rowwise_state(tables: jax.Array) -> jax.Array:
    """(F, V) f32 accumulator — one scalar per row (rowwise AdaGrad)."""
    return jnp.zeros(tables.shape[:-1], jnp.float32)


def sparse_table_update(
    tables: jax.Array,          # (F, V, D)
    acc: jax.Array,             # (F, V) rowwise AdaGrad accumulator
    idx: jax.Array,             # (B, F, MH) int32 — the batch's lookups
    pulled_grad: jax.Array,     # (B, F, MH, D) grad of the gathered rows
    *,
    lr: float = 0.01,
    eps: float = 1e-8,
) -> Tuple[jax.Array, jax.Array]:
    """Apply rowwise AdaGrad to ONLY the rows referenced by ``idx``.

    Duplicate rows within the batch accumulate their gradients first (exact —
    same semantics as the dense update), then each touched row gets
    ``row -= lr * g / sqrt(acc + mean(g²))``.
    """
    B, F, MH, D = pulled_grad.shape
    V = tables.shape[1]

    def per_field(table_f, acc_f, idx_f, g_f):
        flat_idx = idx_f.reshape(-1)            # (B·MH,)
        flat_g = g_f.reshape(-1, D)             # (B·MH, D)
        # combine duplicates: dense-per-batch scatter-add into a V-row zero
        # buffer would defeat the purpose; segment over the batch's own rows.
        g_rows = jax.ops.segment_sum(flat_g, flat_idx, num_segments=V)  # sparse-in-effect
        touched = jax.ops.segment_sum(jnp.ones_like(flat_idx, jnp.float32),
                                      flat_idx, num_segments=V) > 0
        g2 = jnp.mean(g_rows * g_rows, axis=-1)            # (V,) rowwise
        acc_new = acc_f + jnp.where(touched, g2, 0.0)
        scale = lr / jnp.sqrt(acc_new + eps)
        upd = g_rows * scale[:, None]
        table_new = table_f - jnp.where(touched[:, None], upd, 0.0).astype(table_f.dtype)
        return table_new, acc_new

    new_tables, new_acc = jax.vmap(per_field)(
        tables, acc, jnp.swapaxes(idx, 0, 1), jnp.swapaxes(pulled_grad, 0, 1))
    return new_tables, new_acc


def dense_rowwise_update(tables, acc, dense_grad, *, lr: float = 0.01, eps: float = 1e-8):
    """Dense reference implementation (for the equivalence test): rowwise
    AdaGrad applied to every row with nonzero gradient."""
    g2 = jnp.mean(dense_grad * dense_grad, axis=-1)  # (F, V)
    touched = jnp.any(dense_grad != 0, axis=-1)
    acc_new = acc + jnp.where(touched, g2, 0.0)
    scale = lr / jnp.sqrt(acc_new + eps)
    upd = dense_grad * scale[..., None]
    return (tables - jnp.where(touched[..., None], upd, 0.0).astype(tables.dtype),
            acc_new)
