"""AdamW with decoupled weight decay, global-norm clipping and LR schedules.

States are pytrees with the same structure/sharding as params (pjit shards
them with the param rules — with ``shard_weights_over_data`` that is the
ZeRO/FSDP regime: optimizer memory scales 1/|data axis|).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_state", "apply_updates", "cosine_schedule", "constant_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    schedule: str = "cosine"          # 'cosine' | 'constant'
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def constant_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def init_state(params) -> Dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, zeros), "count": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sum(leaves))


def apply_updates(params, grads, state: Dict, cfg: AdamWConfig) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = _global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = cosine_schedule(cfg, count) if cfg.schedule == "cosine" else constant_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        p2 = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
