"""Gradient compression for the DP all-reduce, with error feedback.

At 1000+-node scale the gradient all-reduce crosses the slowest links (DCI
between pods); compressing the payload 4× (f32→int8 with per-tensor scale)
cuts that term directly.  Error feedback (Seide et al. 2014; EF-SGD, Karimireddy
et al. 2019) accumulates the quantization residual locally and re-injects it
next step, preserving convergence (contraction-compressor guarantee).

Usage inside a step function::

    comp_grads, new_err = compress_with_feedback(grads, err_state)
    # all-reduce comp_grads.q (int8) + per-tensor scales, then
    grads = decompress(comp_grads)

Under pjit the int8 payload shows up in the HLO as an int8 all-reduce —
4× fewer collective bytes on the dp axis (verified in tests by dtype).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["CompressedGrads", "init_error_state", "compress_with_feedback", "decompress"]


class CompressedGrads(NamedTuple):
    q: Any      # pytree of int8 tensors
    scale: Any  # pytree of f32 per-tensor scales


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_with_feedback(grads, err_state) -> Tuple[CompressedGrads, Any]:
    """int8-quantize (grads + carried error); returns compressed grads and the
    new error state (the residual the quantizer dropped)."""
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, err_state)
    qs = jax.tree.map(_quantize, corrected)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    scale = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    recon = jax.tree.map(lambda qq, s: qq.astype(jnp.float32) * s, q, scale)
    new_err = jax.tree.map(lambda c, r: c - r, corrected, recon)
    return CompressedGrads(q=q, scale=scale), new_err


def decompress(comp: CompressedGrads):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, comp.q, comp.scale)
